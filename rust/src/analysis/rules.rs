//! The lint rules of `skglm analyze`.
//!
//! Each rule is a pure function over the lexed source model
//! ([`super::lexer::SourceFile`]) plus a little documentation context
//! (ARCHITECTURE.md, scenarios.jsonl). Findings are structured
//! (`rule_id`/`file`/`line`/`severity`/`excerpt`/`justification`) and
//! every rule honours inline `// lint: allow(rule, reason)` suppressions
//! — a suppressed finding is dropped but the suppression itself is
//! inventoried in the report with a `used` flag, so dead allows are
//! visible too.
//!
//! These are *lexical* rules, and deliberately conservative: they encode
//! this repo's invariants (the panic-surviving service loop, the
//! bit-identity contract of `linalg/`+`solver/`, the documented wire
//! error table), not general Rust semantics. Known approximations are
//! documented on each rule.

use super::lexer::{is_ident_char, SourceFile};
use std::collections::{BTreeMap, BTreeSet};

/// One structured finding. `severity` is always `"error"` today (every
/// rule is a CI gate); the field exists so future advisory rules can
/// downgrade without a schema change.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule_id: String,
    pub file: String,
    pub line: usize,
    pub severity: String,
    pub excerpt: String,
    pub justification: String,
}

/// A `lint: allow` suppression, inventoried with whether any rule
/// actually consumed it.
#[derive(Clone, Debug)]
pub struct SuppressionRecord {
    pub rule_id: String,
    pub file: String,
    pub line: usize,
    pub reason: String,
    pub used: bool,
}

/// One `unsafe` occurrence (always inventoried, finding or not).
#[derive(Clone, Debug)]
pub struct UnsafeSite {
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub has_safety: bool,
}

/// Full result of a rule-engine run.
#[derive(Clone, Debug, Default)]
pub struct Outcome {
    pub findings: Vec<Finding>,
    pub suppressions: Vec<SuppressionRecord>,
    pub unsafe_inventory: Vec<UnsafeSite>,
}

/// (id, description) for every shipped rule, in report order.
pub const RULES: [(&str, &str); 7] = [
    (
        "panic-audit",
        "no unwrap/expect/panic!/scalar indexing in non-test coordinator service-path code \
         (service, scheduler, wire, client, cache): the fit service promises to survive bad input",
    ),
    (
        "lock-order",
        "per-function Mutex acquisition sequences must form an acyclic lock graph \
         (two functions taking the same pair of locks in opposite order can deadlock)",
    ),
    (
        "atomic-ordering",
        "every Ordering::Relaxed on a read-modify-write or cross-thread flag store needs a \
         nearby comment justifying why relaxed ordering is sound",
    ),
    (
        "unsafe-audit",
        "every `unsafe` must carry a // SAFETY: comment on the same or the 3 preceding lines; \
         all sites are inventoried in the report",
    ),
    (
        "determinism",
        "no Instant::now/SystemTime::now in linalg/, and no HashMap/HashSet iteration in \
         linalg/ or solver/ (iteration order would break the bit-identity contract)",
    ),
    (
        "doc-conformance",
        "every wire/service error code appears in ARCHITECTURE.md's error table, and every \
         scenarios.jsonl field is known to the Scenario parser",
    ),
    (
        "isa-gate",
        "vendor SIMD intrinsics and #[target_feature] live only in linalg/simd.rs; every \
         #[target_feature] fn there is dispatcher-gated (never plain `pub`) and carries a \
         // SAFETY: comment within the 3 lines above its attribute",
    ),
];

/// External documents the doc-conformance rule checks against.
#[derive(Clone, Debug, Default)]
pub struct DocContext {
    /// ARCHITECTURE.md text ("" when absent).
    pub architecture: String,
    /// scenarios.jsonl text, when present.
    pub scenarios_jsonl: Option<String>,
}

struct Engine<'a> {
    files: &'a [SourceFile],
    findings: Vec<Finding>,
    /// used[file_idx][suppression_idx]
    used: Vec<Vec<bool>>,
}

impl<'a> Engine<'a> {
    fn new(files: &'a [SourceFile]) -> Engine<'a> {
        let used = files.iter().map(|f| vec![false; f.suppressions.len()]).collect();
        Engine { files, findings: Vec::new(), used }
    }

    /// Record a finding unless a matching suppression covers the line
    /// (in which case the suppression is marked used instead).
    fn emit(&mut self, file_idx: usize, rule: &str, line: usize, justification: String) {
        let f = &self.files[file_idx];
        if let Some(si) = f.suppression_for(rule, line) {
            self.used[file_idx][si] = true;
            return;
        }
        self.findings.push(Finding {
            rule_id: rule.to_string(),
            file: f.path.clone(),
            line,
            severity: "error".to_string(),
            excerpt: f.excerpt(line),
            justification,
        });
    }

    /// A finding not tied to any scanned file (e.g. scenarios.jsonl
    /// drift); no suppression channel.
    fn emit_external(&mut self, rule: &str, file: &str, line: usize, excerpt: String, justification: String) {
        self.findings.push(Finding {
            rule_id: rule.to_string(),
            file: file.to_string(),
            line,
            severity: "error".to_string(),
            excerpt,
            justification,
        });
    }
}

/// Run all seven rules over `files`.
pub fn run_all(files: &[SourceFile], docs: &DocContext) -> Outcome {
    let mut eng = Engine::new(files);
    let mut unsafe_inventory = Vec::new();
    panic_audit(&mut eng);
    lock_order(&mut eng);
    atomic_ordering(&mut eng);
    unsafe_audit(&mut eng, &mut unsafe_inventory);
    determinism(&mut eng);
    doc_conformance(&mut eng, docs);
    isa_gate(&mut eng);

    let mut suppressions = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        for (si, s) in f.suppressions.iter().enumerate() {
            // documentation that *describes* the syntax (e.g. `lint:
            // allow(rule, reason)` with a placeholder rule name) is not a
            // suppression; only known rule ids enter the inventory
            if !RULES.iter().any(|(id, _)| *id == s.rule) {
                continue;
            }
            suppressions.push(SuppressionRecord {
                rule_id: s.rule.clone(),
                file: f.path.clone(),
                line: s.line,
                reason: s.reason.clone(),
                used: eng.used[fi][si],
            });
        }
    }
    let mut findings = eng.findings;
    findings.sort_by(|a, b| {
        (&a.rule_id, &a.file, a.line).cmp(&(&b.rule_id, &b.file, b.line))
    });
    Outcome { findings, suppressions, unsafe_inventory }
}

// ---------------------------------------------------------------------
// rule 1: panic-audit
// ---------------------------------------------------------------------

/// Service-path files where a panic kills a connection the wire
/// protocol promised to keep alive.
const PANIC_SCOPE: [&str; 5] = [
    "coordinator/service.rs",
    "coordinator/scheduler.rs",
    "coordinator/wire.rs",
    "coordinator/client.rs",
    "coordinator/cache.rs",
];

fn panic_audit(eng: &mut Engine<'_>) {
    for fi in 0..eng.files.len() {
        let f = &eng.files[fi];
        if !PANIC_SCOPE.iter().any(|s| f.path.ends_with(s)) {
            continue;
        }
        let mut hits: Vec<(usize, String)> = Vec::new();
        for (idx, line) in f.lines.iter().enumerate() {
            if line.is_test {
                continue;
            }
            let code = &line.code;
            if code.contains(".unwrap()") {
                hits.push((idx + 1, ".unwrap() may panic".to_string()));
            }
            if code.contains(".expect(") {
                hits.push((idx + 1, ".expect(..) may panic".to_string()));
            }
            for mac in ["panic!(", "unreachable!(", "todo!(", "unimplemented!("] {
                if has_word_prefix(code, mac) {
                    hits.push((idx + 1, format!("{}..) panics", &mac[..mac.len() - 1])));
                }
            }
            if has_scalar_index(code) {
                hits.push((
                    idx + 1,
                    "scalar indexing panics when out of bounds (range slices are exempt)"
                        .to_string(),
                ));
            }
        }
        for (lineno, what) in hits {
            eng.emit(
                fi,
                "panic-audit",
                lineno,
                format!(
                    "{what}; the service contract requires surviving bad input — handle the \
                     Option/Result, or justify with `// lint: allow(panic-audit, why)`"
                ),
            );
        }
    }
}

/// `pat` (a macro call like `panic!(`) appears with a word boundary on
/// its left, so `log_panic!(..)` or `no_todo!(..)` never match.
fn has_word_prefix(code: &str, pat: &str) -> bool {
    let mut search = 0usize;
    while let Some(rel) = code[search..].find(pat) {
        let at = search + rel;
        if at == 0 || !is_ident_char(code.as_bytes()[at - 1] as char) {
            return true;
        }
        search = at + pat.len();
    }
    false
}

/// Detect `expr[i]`-style scalar indexing: a `[` whose previous
/// non-space char is an identifier char, `)`, or `]` (so array/vec/slice
/// literals, attributes, and types don't match), with a matching `]` on
/// the same line and no `..` inside (range slices never panic here the
/// same way and are exempt by design). A keyword before the `[` (`mut`,
/// `in`, `return`, …) means a type or array expression, not an index.
fn has_scalar_index(code: &str) -> bool {
    const KEYWORDS: [&str; 12] = [
        "mut", "in", "return", "if", "else", "match", "let", "as", "dyn", "ref", "move", "box",
    ];
    let chars: Vec<char> = code.chars().collect();
    for (i, &c) in chars.iter().enumerate() {
        if c != '[' {
            continue;
        }
        let head = chars[..i]
            .iter()
            .rev()
            .skip_while(|ch| ch.is_whitespace())
            .take_while(|ch| is_ident_char(**ch))
            .collect::<String>();
        let word: String = head.chars().rev().collect();
        let prev = chars[..i].iter().rev().find(|ch| !ch.is_whitespace());
        let indexes = matches!(prev, Some(&p) if is_ident_char(p) || p == ')' || p == ']');
        if !indexes || KEYWORDS.contains(&word.as_str()) {
            continue;
        }
        let mut depth = 1usize;
        let mut j = i + 1;
        while j < chars.len() && depth > 0 {
            match chars[j] {
                '[' => depth += 1,
                ']' => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        if depth == 0 {
            let interior: String = chars[i + 1..j - 1].iter().collect();
            if !interior.contains("..") && !interior.trim().is_empty() {
                return true;
            }
        }
    }
    false
}

// ---------------------------------------------------------------------
// rule 2: lock-order
// ---------------------------------------------------------------------

/// Build the lock graph from per-function acquisition sequences and
/// fail on cycles.
///
/// A lock is identified as `<file stem>::<field name>` (the identifier
/// left of `.lock()`, or the argument of `lock_or_recover(..)`). Within
/// one function, every ordered pair (first acquired → later acquired)
/// becomes an edge. This over-approximates: it cannot see guard drops,
/// so two locks taken *sequentially* in one function count as ordered —
/// conservative, and it keeps the whole codebase on one global lock
/// order, which is the invariant we actually want.
fn lock_order(eng: &mut Engine<'_>) {
    // edge -> representative acquisition site (file_idx, line, fn name)
    let mut edges: BTreeMap<(String, String), (usize, usize, String)> = BTreeMap::new();
    for fi in 0..eng.files.len() {
        let f = &eng.files[fi];
        let stem = file_stem(&f.path);
        for span in &f.fns {
            let mut seq: Vec<(String, usize)> = Vec::new();
            for lineno in span.start..=span.end {
                let line = &f.lines[lineno - 1];
                if line.is_test {
                    continue;
                }
                for name in lock_names(&line.code) {
                    let id = format!("{stem}::{name}");
                    if !seq.iter().any(|(n, _)| *n == id) {
                        seq.push((id, lineno));
                    }
                }
            }
            for i in 0..seq.len() {
                for j in (i + 1)..seq.len() {
                    let key = (seq[i].0.clone(), seq[j].0.clone());
                    edges
                        .entry(key)
                        .or_insert((fi, seq[j].1, span.name.clone()));
                }
            }
        }
    }

    // adjacency (every node present, even sink-only ones)
    let mut adj: BTreeMap<String, Vec<String>> = BTreeMap::new();
    for (from, to) in edges.keys() {
        adj.entry(from.clone()).or_default().push(to.clone());
        adj.entry(to.clone()).or_default();
    }
    // iterative DFS cycle detection (0 = unvisited, 1 = on stack, 2 = done)
    let mut state: BTreeMap<String, u8> = adj.keys().map(|k| (k.clone(), 0u8)).collect();
    let starts: Vec<String> = adj.keys().cloned().collect();
    for start in starts {
        if state[&start] != 0 {
            continue;
        }
        let mut stack: Vec<(String, usize)> = vec![(start.clone(), 0)];
        let mut path: Vec<String> = vec![start.clone()];
        state.insert(start, 1);
        while let Some((node, cursor)) = stack.last().cloned() {
            let succs = &adj[&node];
            if cursor < succs.len() {
                if let Some(top) = stack.last_mut() {
                    top.1 += 1;
                }
                let succ = succs[cursor].clone();
                match state[&succ] {
                    0 => {
                        state.insert(succ.clone(), 1);
                        stack.push((succ.clone(), 0));
                        path.push(succ);
                    }
                    1 => {
                        // back edge: the cycle is `path` from succ onward
                        let from = path.iter().position(|n| *n == succ).unwrap_or(0);
                        let mut cycle: Vec<String> = path[from..].to_vec();
                        cycle.push(succ.clone());
                        // anchor the finding at the back edge's site
                        let key = (node.clone(), succ.clone());
                        let (fi, lineno, fn_name) =
                            edges.get(&key).cloned().unwrap_or((0, 1, String::new()));
                        eng.emit(
                            fi,
                            "lock-order",
                            lineno,
                            format!(
                                "lock cycle {} (closing edge acquired in fn {fn_name}); \
                                 pick one global acquisition order",
                                cycle.join(" -> ")
                            ),
                        );
                        state.insert(succ, 2); // report each cycle once
                    }
                    _ => {}
                }
            } else {
                state.insert(node, 2);
                stack.pop();
                path.pop();
            }
        }
    }
}

fn file_stem(path: &str) -> String {
    path.rsplit('/')
        .next()
        .unwrap_or(path)
        .trim_end_matches(".rs")
        .to_string()
}

/// Lock acquisitions on one code line, in positional order: the field
/// name left of `.lock()`, or the argument of `lock_or_recover(&x)` /
/// `lock_or_recover(&self.x)`. `wait_or_recover` re-acquires the same
/// guard and is not a new acquisition.
fn lock_names(code: &str) -> Vec<String> {
    let mut hits: Vec<(usize, String)> = Vec::new();
    let mut search = 0usize;
    while let Some(rel) = code[search..].find(".lock()") {
        let at = search + rel;
        if let Some(name) = ident_chain_before(code, at) {
            hits.push((at, name));
        }
        search = at + ".lock()".len();
    }
    search = 0;
    while let Some(rel) = code[search..].find("lock_or_recover(") {
        let at = search + rel;
        if at == 0 || !is_ident_char(code.as_bytes()[at - 1] as char) {
            let arg = &code[at + "lock_or_recover(".len()..];
            let arg = arg.trim_start().trim_start_matches('&').trim_start();
            let chain: String = arg
                .chars()
                .take_while(|&c| is_ident_char(c) || c == '.')
                .collect();
            if let Some(last) = last_component(&chain) {
                hits.push((at, last));
            }
        }
        search = at + "lock_or_recover(".len();
    }
    hits.sort_by_key(|(pos, _)| *pos);
    hits.into_iter().map(|(_, n)| n).collect()
}

/// The identifier chain ending at byte `at` (e.g. for `self.inner.lock()`
/// with `at` on the final `.`, yields `inner`).
fn ident_chain_before(code: &str, at: usize) -> Option<String> {
    let head: Vec<char> = code[..at].chars().collect();
    let mut i = head.len();
    while i > 0 && (is_ident_char(head[i - 1]) || head[i - 1] == '.') {
        i -= 1;
    }
    let chain: String = head[i..].iter().collect();
    last_component(&chain)
}

fn last_component(chain: &str) -> Option<String> {
    chain
        .split('.')
        .filter(|c| !c.is_empty() && *c != "self")
        .next_back()
        .map(|s| s.to_string())
}

// ---------------------------------------------------------------------
// rule 3: atomic-ordering
// ---------------------------------------------------------------------

const RMW_OPS: [&str; 10] = [
    ".fetch_add(",
    ".fetch_sub(",
    ".fetch_or(",
    ".fetch_and(",
    ".fetch_xor(",
    ".fetch_max(",
    ".fetch_min(",
    ".fetch_update(",
    ".compare_exchange",
    ".swap(",
];

/// Flag `Ordering::Relaxed` on read-modify-write operations and on
/// cross-thread boolean flag stores (`.store(true/false, Relaxed)`)
/// unless a comment mentioning "relaxed" sits on the same line or the 4
/// preceding lines. Relaxed *loads* are exempt: the paired store site is
/// where the justification lives.
fn atomic_ordering(eng: &mut Engine<'_>) {
    for fi in 0..eng.files.len() {
        let f = &eng.files[fi];
        let mut hits: Vec<(usize, String)> = Vec::new();
        for (idx, line) in f.lines.iter().enumerate() {
            if line.is_test || !line.code.contains("Relaxed") {
                continue;
            }
            let code = &line.code;
            let rmw = RMW_OPS.iter().find(|op| code.contains(*op));
            let flag_store = code.contains(".store(true") || code.contains(".store(false");
            let what = match (rmw, flag_store) {
                (Some(op), _) => format!(
                    "relaxed read-modify-write ({})",
                    op.trim_start_matches('.').trim_end_matches('(')
                ),
                (None, true) => "relaxed cross-thread flag store".to_string(),
                (None, false) => continue,
            };
            if comment_nearby(f, idx + 1, 4, "relaxed") {
                continue;
            }
            hits.push((idx + 1, what));
        }
        for (lineno, what) in hits {
            eng.emit(
                fi,
                "atomic-ordering",
                lineno,
                format!(
                    "{what} without a justification comment; explain why Relaxed is sound here \
                     (what the op synchronises with, or why it needs no ordering) in a comment \
                     containing the word \"relaxed\""
                ),
            );
        }
    }
}

/// A comment on line `lineno` or its `window` preceding lines contains
/// `needle` (case-insensitive).
fn comment_nearby(f: &SourceFile, lineno: usize, window: usize, needle: &str) -> bool {
    let lo = lineno.saturating_sub(window).max(1);
    (lo..=lineno).any(|l| {
        f.lines
            .get(l - 1)
            .map(|line| line.comment.to_ascii_lowercase().contains(needle))
            .unwrap_or(false)
    })
}

// ---------------------------------------------------------------------
// rule 4: unsafe-audit
// ---------------------------------------------------------------------

/// Every `unsafe` (blocks and `unsafe impl`) must carry a `SAFETY:`
/// comment on the same line or within the 3 preceding lines; all sites
/// are inventoried regardless.
fn unsafe_audit(eng: &mut Engine<'_>, inventory: &mut Vec<UnsafeSite>) {
    for fi in 0..eng.files.len() {
        let f = &eng.files[fi];
        let mut hits: Vec<usize> = Vec::new();
        for (idx, line) in f.lines.iter().enumerate() {
            if has_word(&line.code, "unsafe") {
                let lineno = idx + 1;
                let has_safety = safety_nearby(f, lineno, 3);
                inventory.push(UnsafeSite {
                    file: f.path.clone(),
                    line: lineno,
                    excerpt: f.excerpt(lineno),
                    has_safety,
                });
                if !has_safety {
                    hits.push(lineno);
                }
            }
        }
        for lineno in hits {
            eng.emit(
                fi,
                "unsafe-audit",
                lineno,
                "`unsafe` without a `// SAFETY:` comment on the same or the 3 preceding lines; \
                 state the invariant that makes this sound"
                    .to_string(),
            );
        }
    }
}

fn safety_nearby(f: &SourceFile, lineno: usize, window: usize) -> bool {
    let lo = lineno.saturating_sub(window).max(1);
    (lo..=lineno).any(|l| {
        f.lines
            .get(l - 1)
            .map(|line| line.comment.contains("SAFETY:"))
            .unwrap_or(false)
    })
}

/// `word` appears in `code` with identifier boundaries on both sides.
fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut search = 0usize;
    while let Some(rel) = code[search..].find(word) {
        let at = search + rel;
        let left_ok = at == 0 || !is_ident_char(bytes[at - 1] as char);
        let end = at + word.len();
        let right_ok = end >= bytes.len() || !is_ident_char(bytes[end] as char);
        if left_ok && right_ok {
            return true;
        }
        search = at + word.len();
    }
    false
}

// ---------------------------------------------------------------------
// rule 5: determinism
// ---------------------------------------------------------------------

/// Guard the bit-identity contract: `linalg/` must not read wall-clock
/// time (`Instant::now` / `SystemTime::now`), and neither `linalg/` nor
/// `solver/` may *iterate* a `HashMap`/`HashSet` (keyed lookups are
/// fine; iteration order is nondeterministic and must never feed
/// numeric accumulation). `solver/` wall-clock reads are deliberately
/// exempt: deadlines and profiling are an intentional, documented
/// wall-clock dependence that never feeds the iterate sequence.
fn determinism(eng: &mut Engine<'_>) {
    for fi in 0..eng.files.len() {
        let f = &eng.files[fi];
        let in_linalg = f.path.contains("linalg/");
        let in_solver = f.path.contains("solver/");
        if !in_linalg && !in_solver {
            continue;
        }
        let mut hits: Vec<(usize, String)> = Vec::new();

        if in_linalg {
            for (idx, line) in f.lines.iter().enumerate() {
                if line.is_test {
                    continue;
                }
                for pat in ["Instant::now", "SystemTime::now"] {
                    if line.code.contains(pat) {
                        hits.push((
                            idx + 1,
                            format!(
                                "{pat} in a linalg hot path; kernel results must be a pure \
                                 function of their inputs (bit-identity across runs and thread \
                                 counts)"
                            ),
                        ));
                    }
                }
            }
        }

        // pass 1: names bound to hash containers in this file
        let mut maps: BTreeSet<String> = BTreeSet::new();
        for line in &f.lines {
            for ty in ["HashMap<", "HashSet<", "HashMap::", "HashSet::"] {
                if let Some(name) = binding_before_type(&line.code, ty) {
                    maps.insert(name);
                }
            }
        }
        // pass 2: iteration over any of those names
        for (idx, line) in f.lines.iter().enumerate() {
            if line.is_test {
                continue;
            }
            for name in &maps {
                if iterates(&line.code, name) {
                    hits.push((
                        idx + 1,
                        format!(
                            "iteration over hash container `{name}`; HashMap/HashSet order is \
                             nondeterministic and breaks the bit-identity contract — iterate a \
                             sorted working set (or a Vec) instead"
                        ),
                    ));
                    break;
                }
            }
        }
        for (lineno, what) in hits {
            eng.emit(fi, "determinism", lineno, what);
        }
    }
}

/// For a line mentioning a hash-container type (`x: HashMap<..>` field
/// or `let x = HashMap::new()` binding), extract the bound identifier.
fn binding_before_type(code: &str, ty: &str) -> Option<String> {
    let at = code.find(ty)?;
    let mut head = code[..at].trim_end();
    // strip a path prefix like `std::collections::`
    while let Some(stripped) = head.strip_suffix("::") {
        let mut h = stripped;
        while h
            .chars()
            .next_back()
            .map(is_ident_char)
            .unwrap_or(false)
        {
            h = &h[..h.len() - 1];
        }
        head = h.trim_end();
    }
    if let Some(h) = head.strip_suffix(':') {
        // `name: HashMap<..>` (field or annotated let)
        return trailing_ident(h);
    }
    if let Some(h) = head.strip_suffix('=') {
        // `let name = HashMap::new()`
        return trailing_ident(h);
    }
    None
}

fn trailing_ident(s: &str) -> Option<String> {
    let s = s.trim_end();
    let name: String = s
        .chars()
        .rev()
        .take_while(|&c| is_ident_char(c))
        .collect::<Vec<char>>()
        .into_iter()
        .rev()
        .collect();
    if name.is_empty() || name.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(true) {
        None
    } else {
        Some(name)
    }
}

/// Does this line iterate container `name`? Checks iterator-producing
/// method calls and `for .. in` loops.
fn iterates(code: &str, name: &str) -> bool {
    for m in [
        ".iter()",
        ".iter_mut()",
        ".keys()",
        ".values()",
        ".values_mut()",
        ".into_iter()",
        ".drain(",
        ".retain(",
    ] {
        let pat = format!("{name}{m}");
        let mut search = 0usize;
        while let Some(rel) = code[search..].find(&pat) {
            let at = search + rel;
            if at == 0 || !is_ident_char(code.as_bytes()[at - 1] as char) {
                return true;
            }
            search = at + pat.len();
        }
    }
    if code.contains("for ") {
        if let Some(at) = code.rfind(" in ") {
            let mut expr = code[at + 4..].trim_start();
            expr = expr.trim_start_matches('&');
            expr = expr.strip_prefix("mut ").unwrap_or(expr).trim_start();
            expr = expr.strip_prefix("self.").unwrap_or(expr);
            if let Some(rest) = expr.strip_prefix(name) {
                let boundary = rest.chars().next().map(|c| !is_ident_char(c)).unwrap_or(true);
                // `map.keys()` etc already matched above; a bare `for k in map {`
                // or `for k in &map {` iterates directly
                let direct = rest.trim_start().is_empty()
                    || rest.trim_start().starts_with('{')
                    || rest.starts_with(' ');
                if boundary && direct {
                    return true;
                }
            }
        }
    }
    false
}

// ---------------------------------------------------------------------
// rule 6: doc-conformance
// ---------------------------------------------------------------------

/// Cross-check code against documentation:
/// - every `WireError::code()` string in `coordinator/wire.rs` and every
///   literal error code passed to `error_frame(..)` in
///   `coordinator/service.rs` must appear backticked in ARCHITECTURE.md;
/// - every field key used in `scenarios.jsonl` must be a known field of
///   the `Scenario::from_json` parser.
fn doc_conformance(eng: &mut Engine<'_>, docs: &DocContext) {
    // (file_idx, line, code string)
    let mut codes: Vec<(usize, usize, String)> = Vec::new();
    for fi in 0..eng.files.len() {
        let f = &eng.files[fi];
        if f.path.ends_with("coordinator/wire.rs") {
            // string literals inside `fn code(..)`
            if let Some(span) = f.fns.iter().find(|s| s.name == "code") {
                for lineno in span.start..=span.end {
                    for s in &f.lines[lineno - 1].strings {
                        if looks_like_code(s) {
                            codes.push((fi, lineno, s.clone()));
                        }
                    }
                }
            }
        }
        if f.path.ends_with("coordinator/service.rs") {
            // first string literal at (or within 3 lines below) each
            // error_frame(..) *call* — the definition line is skipped,
            // and calls forwarding a computed code have no literal
            for (idx, line) in f.lines.iter().enumerate() {
                if !line.code.contains("error_frame(") || line.code.contains("fn error_frame") {
                    continue;
                }
                'win: for l in idx..(idx + 4).min(f.lines.len()) {
                    for s in &f.lines[l].strings {
                        if looks_like_code(s) {
                            codes.push((fi, idx + 1, s.clone()));
                        }
                        break 'win; // first literal only, code-like or not
                    }
                }
            }
        }
    }
    let mut seen: BTreeSet<String> = BTreeSet::new();
    for (fi, lineno, code) in codes {
        if !seen.insert(code.clone()) {
            continue;
        }
        let backticked = format!("`{code}`");
        if !docs.architecture.contains(&backticked) {
            eng.emit(
                fi,
                "doc-conformance",
                lineno,
                format!(
                    "error code \"{code}\" is not in ARCHITECTURE.md's error-code table; \
                     clients key on documented codes — add it to the table"
                ),
            );
        }
    }

    // scenarios.jsonl fields vs the Scenario::from_json known-field list
    let mut known: BTreeSet<String> = BTreeSet::new();
    for f in eng.files {
        if !f.path.ends_with("bench/scenario.rs") {
            continue;
        }
        if let Some(span) = f.fns.iter().find(|s| s.name == "from_json") {
            for lineno in span.start..=span.end {
                let line = &f.lines[lineno - 1];
                // match arms lex as `"" =>` with the field name in strings
                if line.code.trim_start().starts_with("\"\" =>") {
                    if let Some(s) = line.strings.first() {
                        known.insert(s.clone());
                    }
                }
            }
        }
    }
    if let (Some(jsonl), false) = (&docs.scenarios_jsonl, known.is_empty()) {
        for (idx, raw) in jsonl.lines().enumerate() {
            let raw = raw.trim();
            if raw.is_empty() || raw.starts_with('#') {
                continue;
            }
            let parsed = match crate::util::json::Json::parse(raw) {
                Ok(j) => j,
                Err(e) => {
                    eng.emit_external(
                        "doc-conformance",
                        "scenarios.jsonl",
                        idx + 1,
                        truncate(raw, 80),
                        format!("line does not parse as JSON: {e}"),
                    );
                    continue;
                }
            };
            if let Some(fields) = parsed.fields() {
                for (key, _) in fields {
                    if !known.contains(key) {
                        eng.emit_external(
                            "doc-conformance",
                            "scenarios.jsonl",
                            idx + 1,
                            truncate(raw, 80),
                            format!(
                                "field \"{key}\" is unknown to Scenario::from_json (known: {}); \
                                 the parser rejects it at load time",
                                known.iter().cloned().collect::<Vec<_>>().join(", ")
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// Error codes are lowercase snake_case tokens; filters out message
/// literals that share a line with a code.
fn looks_like_code(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= 32
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..s.char_indices().take_while(|(i, _)| *i < n).last().map(|(i, c)| i + c.len_utf8()).unwrap_or(0)])
    }
}

// ---------------------------------------------------------------------
// rule 7: isa-gate
// ---------------------------------------------------------------------

/// The one file allowed to contain vendor SIMD.
const ISA_HOME: &str = "linalg/simd.rs";

/// Call/path prefixes that mark vendor SIMD usage: the `arch` module
/// paths, x86 `_mm*` intrinsics, and the aarch64 NEON families used by
/// the kernels. Matched with a word boundary on the left, so e.g. a
/// `dot_mm256_like` identifier never trips it.
const INTRINSIC_TOKENS: [&str; 8] = [
    "core::arch",
    "std::arch",
    "_mm256_",
    "_mm_",
    "vld1q_",
    "vst1q_",
    "vfmaq_",
    "vaddvq_",
];

/// Keep every vendor intrinsic behind the one runtime dispatcher:
/// - intrinsic tokens and `#[target_feature]` may appear only in
///   `linalg/simd.rs`, where dispatch guarantees the feature was
///   detected before any variant runs;
/// - inside simd.rs, every `#[target_feature]` attribute needs a
///   `// SAFETY:` comment on its own or the 3 preceding lines (why the
///   feature is guaranteed when this variant is selected), and the fn
///   it gates must not be plain `pub` — `pub(super)`/`pub(crate)`/
///   private keeps the unsafe variants unreachable except through the
///   bounds-checking dispatch wrappers.
fn isa_gate(eng: &mut Engine<'_>) {
    for fi in 0..eng.files.len() {
        let f = &eng.files[fi];
        let in_home = f.path.ends_with(ISA_HOME);
        let mut hits: Vec<(usize, String)> = Vec::new();
        for (idx, line) in f.lines.iter().enumerate() {
            if line.is_test {
                continue;
            }
            let code = &line.code;
            if !in_home {
                for tok in INTRINSIC_TOKENS {
                    if has_word_prefix(code, tok) {
                        hits.push((
                            idx + 1,
                            format!(
                                "vendor intrinsic `{tok}…` outside {ISA_HOME}; SIMD must go \
                                 through the runtime-dispatched `linalg::simd` kernels"
                            ),
                        ));
                        break;
                    }
                }
                if code.contains("#[target_feature") {
                    hits.push((
                        idx + 1,
                        format!(
                            "#[target_feature] outside {ISA_HOME}; feature-gated code belongs \
                             behind the `linalg::simd` dispatcher"
                        ),
                    ));
                }
                continue;
            }
            if !code.contains("#[target_feature") {
                continue;
            }
            let lineno = idx + 1;
            if !safety_nearby(f, lineno, 3) {
                hits.push((
                    lineno,
                    "#[target_feature] without a nearby `// SAFETY:` comment; state why the \
                     feature is guaranteed when this variant is selected"
                        .to_string(),
                ));
            }
            for l in idx + 1..(idx + 4).min(f.lines.len()) {
                let head = f.lines[l].code.trim_start();
                if !head.contains("fn ") {
                    continue;
                }
                if head.starts_with("pub ") && !head.starts_with("pub(") {
                    hits.push((
                        l + 1,
                        "#[target_feature] fn exported as plain `pub`; keep ISA variants \
                         pub(super)/pub(crate) so they are only reachable through the dispatch \
                         wrappers that checked the feature"
                            .to_string(),
                    ));
                }
                break;
            }
        }
        for (lineno, what) in hits {
            eng.emit(fi, "isa-gate", lineno, what);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::SourceFile;

    fn run_src(path: &str, src: &str) -> Outcome {
        let files = vec![SourceFile::parse(path, src)];
        run_all(&files, &DocContext::default())
    }

    fn rule_hits<'a>(out: &'a Outcome, rule: &str) -> Vec<&'a Finding> {
        out.findings.iter().filter(|f| f.rule_id == rule).collect()
    }

    // ---- panic-audit ----

    #[test]
    fn panic_audit_flags_unwrap_expect_macros_and_indexing() {
        let src = "fn f(v: Vec<u8>) {\n\
                   let a = v.first().unwrap();\n\
                   let b = v.first().expect(\"x\");\n\
                   panic!(\"boom\");\n\
                   let c = v[0];\n\
                   }\n";
        let out = run_src("rust/src/coordinator/wire.rs", src);
        assert_eq!(rule_hits(&out, "panic-audit").len(), 4, "{:?}", out.findings);
    }

    #[test]
    fn panic_audit_is_scoped_ignores_tests_ranges_and_unwrap_or() {
        let clean = "fn f(v: Vec<u8>) {\n\
                     let a = v.first().copied().unwrap_or(0);\n\
                     let b = v.first().copied().unwrap_or_else(|| 0);\n\
                     let s = &v[1..3];\n\
                     let t = &v[..];\n\
                     }\n\
                     #[cfg(test)]\n\
                     mod tests {\n\
                     fn t() { Some(1).unwrap(); }\n\
                     }\n";
        let out = run_src("rust/src/coordinator/service.rs", clean);
        assert!(rule_hits(&out, "panic-audit").is_empty(), "{:?}", out.findings);
        // same panicky code outside the scoped files is not this rule's business
        let out = run_src("rust/src/solver/outer.rs", "fn f() { Some(1).unwrap(); }\n");
        assert!(rule_hits(&out, "panic-audit").is_empty());
    }

    #[test]
    fn panic_audit_suppression_is_honoured_and_inventoried() {
        let src = "fn f(v: Vec<u8>) {\n\
                   // lint: allow(panic-audit, length checked by caller)\n\
                   let c = v[0];\n\
                   }\n";
        let out = run_src("rust/src/coordinator/cache.rs", src);
        assert!(rule_hits(&out, "panic-audit").is_empty(), "{:?}", out.findings);
        assert_eq!(out.suppressions.len(), 1);
        assert!(out.suppressions[0].used);
        assert_eq!(out.suppressions[0].reason, "length checked by caller");
    }

    #[test]
    fn unused_suppression_is_inventoried_as_unused() {
        let src = "// lint: allow(panic-audit, nothing here panics)\nfn f() { let x = 1; }\n";
        let out = run_src("rust/src/coordinator/cache.rs", src);
        assert_eq!(out.suppressions.len(), 1);
        assert!(!out.suppressions[0].used);
    }

    // ---- lock-order ----

    #[test]
    fn lock_order_flags_a_cycle() {
        let src = "fn ab(&self) {\n\
                   let a = self.alpha.lock().unwrap();\n\
                   let b = self.beta.lock().unwrap();\n\
                   }\n\
                   fn ba(&self) {\n\
                   let b = self.beta.lock().unwrap();\n\
                   let a = self.alpha.lock().unwrap();\n\
                   }\n";
        let out = run_src("rust/src/coordinator/scheduler.rs", src);
        let hits = rule_hits(&out, "lock-order");
        assert_eq!(hits.len(), 1, "{:?}", out.findings);
        assert!(hits[0].justification.contains("alpha"), "{}", hits[0].justification);
        assert!(hits[0].justification.contains("beta"));
    }

    #[test]
    fn lock_order_consistent_order_is_clean() {
        let src = "fn ab(&self) {\n\
                   let a = self.alpha.lock().unwrap();\n\
                   let b = self.beta.lock().unwrap();\n\
                   }\n\
                   fn ab2(&self) {\n\
                   let a = lock_or_recover(&self.alpha);\n\
                   let b = lock_or_recover(&self.beta);\n\
                   }\n";
        let out = run_src("rust/src/coordinator/scheduler.rs", src);
        assert!(rule_hits(&out, "lock-order").is_empty(), "{:?}", out.findings);
    }

    #[test]
    fn lock_order_suppression_applies() {
        let src = "fn ab(&self) {\n\
                   let a = self.alpha.lock().unwrap();\n\
                   let b = self.beta.lock().unwrap();\n\
                   }\n\
                   fn ba(&self) {\n\
                   let b = self.beta.lock().unwrap();\n\
                   // lint: allow(lock-order, guards are dropped between acquisitions)\n\
                   let a = self.alpha.lock().unwrap();\n\
                   }\n";
        let out = run_src("rust/src/coordinator/scheduler.rs", src);
        assert!(rule_hits(&out, "lock-order").is_empty(), "{:?}", out.findings);
        assert!(out.suppressions.iter().any(|s| s.rule_id == "lock-order" && s.used));
    }

    // ---- atomic-ordering ----

    #[test]
    fn atomic_ordering_flags_unjustified_rmw_and_flag_store() {
        let src = "fn f(&self) {\n\
                   self.next.fetch_add(1, Ordering::Relaxed);\n\
                   self.done.store(true, Ordering::Relaxed);\n\
                   }\n";
        let out = run_src("rust/src/coordinator/pool.rs", src);
        assert_eq!(rule_hits(&out, "atomic-ordering").len(), 2, "{:?}", out.findings);
    }

    #[test]
    fn atomic_ordering_justified_or_non_relaxed_is_clean() {
        let src = "fn f(&self) {\n\
                   // relaxed is fine: the counter is only read after join()\n\
                   self.next.fetch_add(1, Ordering::Relaxed);\n\
                   self.done.store(true, Ordering::Release);\n\
                   let v = self.next.load(Ordering::Relaxed);\n\
                   let _ = v;\n\
                   }\n";
        let out = run_src("rust/src/coordinator/pool.rs", src);
        assert!(rule_hits(&out, "atomic-ordering").is_empty(), "{:?}", out.findings);
    }

    #[test]
    fn atomic_ordering_suppression_applies() {
        let src = "fn f(&self) {\n\
                   // lint: allow(atomic-ordering, counter is advisory)\n\
                   self.next.fetch_add(1, Ordering::Relaxed);\n\
                   }\n";
        let out = run_src("rust/src/linalg/parallel.rs", src);
        assert!(rule_hits(&out, "atomic-ordering").is_empty(), "{:?}", out.findings);
        assert!(out.suppressions[0].used);
    }

    // ---- unsafe-audit ----

    #[test]
    fn unsafe_audit_flags_missing_safety_and_inventories_all() {
        let src = "fn f(p: *mut f64) {\n\
                   unsafe { *p = 1.0; }\n\
                   // SAFETY: p is valid for writes, established by caller\n\
                   unsafe { *p = 2.0; }\n\
                   }\n";
        let out = run_src("rust/src/linalg/parallel.rs", src);
        assert_eq!(rule_hits(&out, "unsafe-audit").len(), 1, "{:?}", out.findings);
        assert_eq!(out.unsafe_inventory.len(), 2);
        assert!(!out.unsafe_inventory[0].has_safety);
        assert!(out.unsafe_inventory[1].has_safety);
    }

    #[test]
    fn unsafe_in_strings_or_comments_is_inert() {
        let src = "fn f() { let s = \"unsafe\"; } // unsafe in prose only\n";
        let out = run_src("rust/src/linalg/parallel.rs", src);
        assert!(out.unsafe_inventory.is_empty());
        assert!(rule_hits(&out, "unsafe-audit").is_empty());
    }

    #[test]
    fn unsafe_audit_suppression_applies() {
        let src = "// lint: allow(unsafe-audit, documented at module level)\n\
                   unsafe fn g() {}\n";
        let out = run_src("rust/src/linalg/parallel.rs", src);
        assert!(rule_hits(&out, "unsafe-audit").is_empty(), "{:?}", out.findings);
        assert!(out.suppressions[0].used);
        assert_eq!(out.unsafe_inventory.len(), 1, "inventory is unconditional");
    }

    // ---- determinism ----

    #[test]
    fn determinism_flags_clock_and_map_iteration() {
        let src = "use std::collections::HashMap;\n\
                   struct S { slot: HashMap<usize, usize> }\n\
                   fn f(s: &S) -> f64 {\n\
                   let t = Instant::now();\n\
                   let mut acc = 0.0;\n\
                   for (_, v) in s.slot.iter() { acc += *v as f64; }\n\
                   let _ = t;\n\
                   acc\n\
                   }\n";
        let out = run_src("rust/src/linalg/gram.rs", src);
        let hits = rule_hits(&out, "determinism");
        assert_eq!(hits.len(), 2, "{:?}", out.findings);
    }

    #[test]
    fn determinism_keyed_lookup_and_solver_clock_are_clean() {
        let src = "use std::collections::HashMap;\n\
                   struct S { slot: HashMap<usize, usize> }\n\
                   fn f(s: &S, j: usize) -> usize {\n\
                   let deadline = Instant::now();\n\
                   let _ = deadline;\n\
                   *s.slot.get(&j).unwrap_or(&0)\n\
                   }\n";
        // solver/: wall clock allowed (deadlines), keyed lookups always fine
        let out = run_src("rust/src/solver/outer.rs", src);
        assert!(rule_hits(&out, "determinism").is_empty(), "{:?}", out.findings);
        // outside linalg//solver/ entirely: out of scope
        let out = run_src("rust/src/bench/harness.rs", "fn f() { let t = Instant::now(); let _ = t; }\n");
        assert!(rule_hits(&out, "determinism").is_empty());
    }

    #[test]
    fn determinism_for_loop_over_set_and_suppression() {
        let src = "use std::collections::HashSet;\n\
                   fn f() {\n\
                   let keep: HashSet<usize> = HashSet::new();\n\
                   for j in &keep { let _ = j; }\n\
                   }\n";
        let out = run_src("rust/src/linalg/gram.rs", src);
        assert_eq!(rule_hits(&out, "determinism").len(), 1, "{:?}", out.findings);
        let src = "use std::collections::HashSet;\n\
                   fn f() {\n\
                   let keep: HashSet<usize> = HashSet::new();\n\
                   // lint: allow(determinism, order does not feed numerics here)\n\
                   for j in &keep { let _ = j; }\n\
                   }\n";
        let out = run_src("rust/src/linalg/gram.rs", src);
        assert!(rule_hits(&out, "determinism").is_empty(), "{:?}", out.findings);
    }

    // ---- doc-conformance ----

    fn wire_src() -> &'static str {
        "impl WireError {\n\
         pub fn code(&self) -> &'static str {\n\
         match self {\n\
         WireError::Io(_) => \"io\",\n\
         WireError::Truncated => \"truncated_frame\",\n\
         }\n\
         }\n\
         }\n"
    }

    #[test]
    fn doc_conformance_flags_missing_code_and_unknown_field() {
        let files = vec![
            SourceFile::parse("rust/src/coordinator/wire.rs", wire_src()),
            SourceFile::parse(
                "rust/src/bench/scenario.rs",
                "fn from_json(j: &Json) -> Result<Scenario> {\n\
                 match key {\n\
                 \"id\" => {}\n\
                 \"n\" => {}\n\
                 }\n\
                 Ok(s)\n\
                 }\n",
            ),
        ];
        let docs = DocContext {
            architecture: "codes: `io` only".to_string(),
            scenarios_jsonl: Some("{\"id\": \"a\", \"bogus\": 1}\n".to_string()),
        };
        let out = run_all(&files, &docs);
        let hits = rule_hits(&out, "doc-conformance");
        assert_eq!(hits.len(), 2, "{:?}", out.findings);
        assert!(hits.iter().any(|h| h.justification.contains("truncated_frame")));
        assert!(hits.iter().any(|h| h.justification.contains("bogus")));
    }

    #[test]
    fn doc_conformance_clean_when_docs_match() {
        let files = vec![
            SourceFile::parse("rust/src/coordinator/wire.rs", wire_src()),
            SourceFile::parse(
                "rust/src/coordinator/service.rs",
                "fn handle(&self) -> Json {\n\
                 error_frame(req, \"bad_request\", \"malformed\")\n\
                 }\n\
                 fn error_frame(req: u64, code: &str, message: &str) -> Json {\n\
                 Json::obj()\n\
                 }\n",
            ),
        ];
        let docs = DocContext {
            architecture: "`io` `truncated_frame` `bad_request`".to_string(),
            scenarios_jsonl: None,
        };
        let out = run_all(&files, &docs);
        assert!(rule_hits(&out, "doc-conformance").is_empty(), "{:?}", out.findings);
    }

    #[test]
    fn doc_conformance_suppression_applies() {
        let files = vec![SourceFile::parse(
            "rust/src/coordinator/wire.rs",
            "impl WireError {\n\
             pub fn code(&self) -> &'static str {\n\
             // lint: allow(doc-conformance, experimental code, not yet documented)\n\
             match self { WireError::New => \"brand_new\" }\n\
             }\n\
             }\n",
        )];
        let docs = DocContext { architecture: String::new(), scenarios_jsonl: None };
        let out = run_all(&files, &docs);
        assert!(rule_hits(&out, "doc-conformance").is_empty(), "{:?}", out.findings);
    }

    // ---- isa-gate ----

    #[test]
    fn isa_gate_flags_intrinsics_and_target_feature_outside_home() {
        let src = "fn f(a: &[f64]) -> f64 {\n\
                   let v = unsafe { _mm256_loadu_pd(a.as_ptr()) };\n\
                   let _ = v;\n\
                   0.0\n\
                   }\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   unsafe fn g() {}\n";
        let out = run_src("rust/src/linalg/dense.rs", src);
        let hits = rule_hits(&out, "isa-gate");
        assert_eq!(hits.len(), 2, "{:?}", out.findings);
        assert!(hits[0].justification.contains("_mm256_"), "{}", hits[0].justification);
    }

    #[test]
    fn isa_gate_home_file_requires_safety_and_gating() {
        // undocumented attribute + plain-pub export: two findings
        let src = "#[target_feature(enable = \"avx2\")]\n\
                   pub unsafe fn dot_avx2(a: &[f64]) -> f64 { 0.0 }\n";
        let out = run_src("rust/src/linalg/simd.rs", src);
        assert_eq!(rule_hits(&out, "isa-gate").len(), 2, "{:?}", out.findings);
        // SAFETY-documented, pub(super)-gated: clean
        let src = "// SAFETY: AVX2 is runtime-detected before dispatch.\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   pub(super) unsafe fn dot_avx2(a: &[f64]) -> f64 { 0.0 }\n";
        let out = run_src("rust/src/linalg/simd.rs", src);
        assert!(rule_hits(&out, "isa-gate").is_empty(), "{:?}", out.findings);
    }

    #[test]
    fn isa_gate_suppression_applies() {
        let src = "fn f(a: &[f64]) {\n\
                   // lint: allow(isa-gate, migration shim, removed next PR)\n\
                   let v = unsafe { _mm_setzero_ps() };\n\
                   let _ = v;\n\
                   }\n";
        let out = run_src("rust/src/linalg/dense.rs", src);
        assert!(rule_hits(&out, "isa-gate").is_empty(), "{:?}", out.findings);
        assert!(out.suppressions.iter().any(|s| s.rule_id == "isa-gate" && s.used));
    }

    // ---- helpers ----

    #[test]
    fn scalar_index_detector_edges() {
        assert!(has_scalar_index("let x = v[0];"));
        assert!(has_scalar_index("let x = self.buf[i + 1];"));
        assert!(!has_scalar_index("let s = &v[1..3];"));
        assert!(!has_scalar_index("let s = &v[..n];"));
        assert!(!has_scalar_index("#[derive(Debug)]"));
        assert!(!has_scalar_index("let a: [u8; 4] = [0; 4];"));
        assert!(!has_scalar_index("fn f(x: &[u8]) {}"));
        assert!(!has_scalar_index("fn f(buf: &mut [u8]) {}"));
        assert!(!has_scalar_index("for x in [1, 2, 3] {}"));
        assert!(!has_scalar_index("return [a, b];"));
        assert!(has_scalar_index("m[&key].push(1);"));
    }

    #[test]
    fn lock_name_extraction() {
        assert_eq!(lock_names("let g = self.state.lock().unwrap();"), vec!["state"]);
        assert_eq!(lock_names("let g = lock_or_recover(&self.jobs);"), vec!["jobs"]);
        assert_eq!(lock_names("let g = util::lock_or_recover(&inner);"), vec!["inner"]);
        assert_eq!(
            lock_names("let a = x.lock().unwrap(); let b = lock_or_recover(&y);"),
            vec!["x", "y"]
        );
        assert!(lock_names("let g = cv.wait_or_recover(guard);").is_empty());
    }

    #[test]
    fn binding_extraction() {
        assert_eq!(
            binding_before_type("    slot: HashMap<usize, usize>,", "HashMap<"),
            Some("slot".to_string())
        );
        assert_eq!(
            binding_before_type(
                "let keep: std::collections::HashSet<usize> = x.collect();",
                "HashSet<"
            ),
            Some("keep".to_string())
        );
        assert_eq!(
            binding_before_type("let mut m = HashMap::new();", "HashMap::"),
            Some("m".to_string())
        );
        assert_eq!(binding_before_type("use std::collections::HashMap;", "HashMap<"), None);
    }
}
