//! Box-indicator penalty `g_j = ι_{[0,C]}` — the dual-SVM constraint
//! (paper §2.1/§E.4). The generalized support (Definition 4) is the set of
//! *free* variables `0 < α_i < C`; bound variables (0 or C) have
//! non-singleton subdifferential and sit outside the gsupp — the paper's
//! showcase that Definition 4 extends beyond sparsity.

use super::Penalty;

#[derive(Clone, Debug)]
pub struct BoxIndicator {
    pub c: f64,
}

impl BoxIndicator {
    pub fn new(c: f64) -> Self {
        assert!(c > 0.0, "box bound C must be positive");
        Self { c }
    }
}

impl Penalty for BoxIndicator {
    #[inline]
    fn value(&self, beta_j: f64, _j: usize) -> f64 {
        if (0.0..=self.c).contains(&beta_j) {
            0.0
        } else {
            f64::INFINITY
        }
    }

    /// Projection onto [0, C] (independent of step).
    #[inline]
    fn prox(&self, v: f64, _step: f64, _j: usize) -> f64 {
        v.clamp(0.0, self.c)
    }

    #[inline]
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, _j: usize) -> f64 {
        if beta_j <= 0.0 {
            // ∂ι(0) = (−∞, 0]: need −grad ≤ 0, violation max(0, −grad)
            (-grad_j).max(0.0)
        } else if beta_j >= self.c {
            // ∂ι(C) = [0, +∞): need −grad ≥ 0, violation max(0, grad)
            grad_j.max(0.0)
        } else {
            // interior: ∂ι = {0}
            grad_j.abs()
        }
    }

    #[inline]
    fn in_gsupp(&self, beta_j: f64) -> bool {
        beta_j > 0.0 && beta_j < self.c
    }

    fn is_convex(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "box_indicator"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prox_is_projection() {
        let p = BoxIndicator::new(2.0);
        assert_eq!(p.prox(-1.0, 0.5, 0), 0.0);
        assert_eq!(p.prox(1.3, 0.5, 0), 1.3);
        assert_eq!(p.prox(5.0, 0.5, 0), 2.0);
    }

    #[test]
    fn value_is_indicator() {
        let p = BoxIndicator::new(2.0);
        assert_eq!(p.value(0.0, 0), 0.0);
        assert_eq!(p.value(2.0, 0), 0.0);
        assert!(p.value(-0.1, 0).is_infinite());
        assert!(p.value(2.1, 0).is_infinite());
    }

    #[test]
    fn kkt_at_bounds() {
        let p = BoxIndicator::new(1.0);
        // at 0: optimal iff grad >= 0
        assert_eq!(p.subdiff_distance(0.0, 0.5, 0), 0.0);
        assert_eq!(p.subdiff_distance(0.0, -0.5, 0), 0.5);
        // at C: optimal iff grad <= 0
        assert_eq!(p.subdiff_distance(1.0, -0.5, 0), 0.0);
        assert_eq!(p.subdiff_distance(1.0, 0.5, 0), 0.5);
        // interior: optimal iff grad == 0
        assert_eq!(p.subdiff_distance(0.5, 0.0, 0), 0.0);
        assert_eq!(p.subdiff_distance(0.5, -0.3, 0), 0.3);
    }

    #[test]
    fn gsupp_is_free_set() {
        let p = BoxIndicator::new(1.0);
        assert!(!p.in_gsupp(0.0));
        assert!(!p.in_gsupp(1.0));
        assert!(p.in_gsupp(0.5));
    }
}
