//! Block-separable penalties `g(v) = Σ_b φ_b(‖v_b‖)` (paper Appendix D)
//! — one trait for the multitask rows *and* the single-task feature
//! groups, consumed by the shared block-coordinate engine
//! ([`crate::solver::block_cd`]). By Proposition 18,
//!
//! ```text
//! prox_{φ(‖·‖)}(x) = prox_φ(‖x‖) · x / ‖x‖ ,
//! ```
//!
//! so each block penalty delegates to its scalar counterpart on the block
//! norm. Block-ℓ2,1 is the convex baseline (multitask Lasso / group
//! Lasso — Figure 4); block-MCP and block-SCAD are the non-convex
//! variants that undo the group-amplitude bias. [`WeightedGroupLasso`]
//! carries per-block weights (`√|b|` by convention) through the block
//! index every method receives.

use super::{Mcp, Penalty, Scad};
use crate::solver::partition::BlockPartition;

/// A block-separable penalty on the packed coefficient vector: block `b`
/// (its values gathered into a slice) is penalised by `φ_b(‖·‖₂)`. The
/// block index threads per-block parameters (weights) through; penalties
/// without per-block state ignore it.
pub trait BlockPenalty: Clone + Send + Sync {
    /// `φ_b(‖block‖)`.
    fn value(&self, block: &[f64], b: usize) -> f64;

    /// In-place `block ← prox_{step·φ_b(‖·‖)}(block)`.
    fn prox(&self, block: &mut [f64], step: f64, b: usize);

    /// `dist(−∇_b f, ∂g_b(block))` for the working-set score.
    fn subdiff_distance(&self, block: &[f64], grad_block: &[f64], b: usize) -> f64;

    /// Generalized support membership for the block.
    fn in_gsupp(&self, block: &[f64]) -> bool {
        block.iter().any(|&v| v != 0.0)
    }

    fn is_convex(&self) -> bool;

    /// Per-block weight in the dual norm `max_b ‖X_bᵀθ‖/w_b` (λ_max
    /// grids, gap-safe block screening). 1 unless the penalty is weighted.
    fn block_weight(&self, _b: usize) -> f64 {
        1.0
    }

    /// Panic if `step = 1/L_b` lies outside the penalty's validity regime
    /// (non-convex semi-convexity, Assumption 6).
    fn validate_step(&self, _step: f64) {}

    fn name(&self) -> &'static str;

    /// `Σ_b φ_b(‖v_b‖)` over a whole partition.
    fn value_sum(&self, v: &[f64], part: &BlockPartition) -> f64 {
        let mut buf = vec![0.0; part.max_block_len()];
        (0..part.n_blocks())
            .map(|b| {
                let sub = &mut buf[..part.block_len(b)];
                part.gather(b, v, sub);
                self.value(sub, b)
            })
            .sum()
    }
}

#[inline]
fn block_norm(block: &[f64]) -> f64 {
    crate::linalg::nrm2(block)
}

/// Apply Proposition 18 given the scalar prox of φ.
#[inline]
fn radial_prox(block: &mut [f64], step: f64, scalar_prox: impl Fn(f64, f64) -> f64) {
    let t = block_norm(block);
    if t == 0.0 {
        return;
    }
    let scale = scalar_prox(t, step) / t;
    for v in block.iter_mut() {
        *v *= scale;
    }
}

/// ‖grad + dir_scale · block/‖block‖‖ — distance for a differentiable-radial φ.
#[inline]
fn radial_dist(block: &[f64], grad_block: &[f64], dir_scale: f64) -> f64 {
    let t = block_norm(block);
    let mut s = 0.0;
    for (&g, &r) in grad_block.iter().zip(block.iter()) {
        let d = g + dir_scale * r / t;
        s += d * d;
    }
    s.sqrt()
}

// ---------------------------------------------------------------- ℓ2,1 --

/// `g(v) = λ Σ_b ‖v_b‖` — multitask Lasso rows / unweighted group Lasso.
#[derive(Clone, Debug)]
pub struct BlockL21 {
    pub lambda: f64,
}

/// Single-task feature-group reading of [`BlockL21`]: the (unweighted)
/// group Lasso penalty. Same mathematics, clearer call sites.
pub type GroupLasso = BlockL21;

impl BlockL21 {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda >= 0.0);
        Self { lambda }
    }
}

impl BlockPenalty for BlockL21 {
    fn value(&self, block: &[f64], _b: usize) -> f64 {
        self.lambda * block_norm(block)
    }

    fn prox(&self, block: &mut [f64], step: f64, _b: usize) {
        let t = block_norm(block);
        if t == 0.0 {
            return;
        }
        let scale = (1.0 - step * self.lambda / t).max(0.0);
        for v in block.iter_mut() {
            *v *= scale;
        }
    }

    fn subdiff_distance(&self, block: &[f64], grad_block: &[f64], _b: usize) -> f64 {
        let t = block_norm(block);
        if t == 0.0 {
            // ∂ at 0 = λ·unit ball: dist = max(0, ‖grad‖ − λ)
            (block_norm(grad_block) - self.lambda).max(0.0)
        } else {
            radial_dist(block, grad_block, self.lambda)
        }
    }

    fn is_convex(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "block_l21"
    }
}

// ------------------------------------------------- weighted group Lasso --

/// `g(β) = λ Σ_b w_b ‖β_b‖` — the weighted group Lasso (`w_b = √|b|` by
/// the yaglm/standard convention, so large groups are not favoured).
#[derive(Clone, Debug)]
pub struct WeightedGroupLasso {
    pub lambda: f64,
    weights: std::sync::Arc<Vec<f64>>,
}

impl WeightedGroupLasso {
    /// Explicit per-block weights (must be positive, one per block).
    pub fn new(lambda: f64, weights: Vec<f64>) -> Self {
        assert!(lambda >= 0.0);
        assert!(!weights.is_empty());
        assert!(weights.iter().all(|&w| w > 0.0), "block weights must be positive");
        Self { lambda, weights: std::sync::Arc::new(weights) }
    }

    /// The standard `w_b = √|b|` weighting for a partition.
    pub fn sqrt_sizes(lambda: f64, part: &BlockPartition) -> Self {
        let w = (0..part.n_blocks()).map(|b| (part.block_len(b) as f64).sqrt()).collect();
        Self::new(lambda, w)
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

impl BlockPenalty for WeightedGroupLasso {
    fn value(&self, block: &[f64], b: usize) -> f64 {
        self.lambda * self.weights[b] * block_norm(block)
    }

    fn prox(&self, block: &mut [f64], step: f64, b: usize) {
        let t = block_norm(block);
        if t == 0.0 {
            return;
        }
        let scale = (1.0 - step * self.lambda * self.weights[b] / t).max(0.0);
        for v in block.iter_mut() {
            *v *= scale;
        }
    }

    fn subdiff_distance(&self, block: &[f64], grad_block: &[f64], b: usize) -> f64 {
        let lam = self.lambda * self.weights[b];
        let t = block_norm(block);
        if t == 0.0 {
            (block_norm(grad_block) - lam).max(0.0)
        } else {
            radial_dist(block, grad_block, lam)
        }
    }

    fn is_convex(&self) -> bool {
        true
    }

    fn block_weight(&self, b: usize) -> f64 {
        self.weights[b]
    }

    fn name(&self) -> &'static str {
        "weighted_group_lasso"
    }
}

// ------------------------------------------------------------ block MCP --

/// `g(v) = Σ_b MCP_{λ,γ}(‖v_b‖)`.
#[derive(Clone, Debug)]
pub struct BlockMcp {
    inner: Mcp,
}

/// Single-task feature-group reading of [`BlockMcp`] (group MCP).
pub type GroupMcp = BlockMcp;

impl BlockMcp {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { inner: Mcp::new(lambda, gamma) }
    }
}

impl BlockPenalty for BlockMcp {
    fn value(&self, block: &[f64], _b: usize) -> f64 {
        self.inner.value(block_norm(block), 0)
    }

    fn prox(&self, block: &mut [f64], step: f64, _b: usize) {
        radial_prox(block, step, |t, s| self.inner.prox(t, s, 0));
    }

    fn subdiff_distance(&self, block: &[f64], grad_block: &[f64], _b: usize) -> f64 {
        let (lam, gam) = (self.inner.lambda, self.inner.gamma);
        let t = block_norm(block);
        if t == 0.0 {
            (block_norm(grad_block) - lam).max(0.0)
        } else if t < gam * lam {
            // MCP'(t) = λ − t/γ
            radial_dist(block, grad_block, lam - t / gam)
        } else {
            block_norm(grad_block)
        }
    }

    fn is_convex(&self) -> bool {
        false
    }

    fn validate_step(&self, step: f64) {
        self.inner.validate_step(step);
    }

    fn name(&self) -> &'static str {
        "block_mcp"
    }
}

// ----------------------------------------------------------- block SCAD --

/// `g(v) = Σ_b SCAD_{λ,γ}(‖v_b‖)`.
#[derive(Clone, Debug)]
pub struct BlockScad {
    inner: Scad,
}

/// Single-task feature-group reading of [`BlockScad`] (group SCAD).
pub type GroupScad = BlockScad;

impl BlockScad {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { inner: Scad::new(lambda, gamma) }
    }
}

impl BlockPenalty for BlockScad {
    fn value(&self, block: &[f64], _b: usize) -> f64 {
        self.inner.value(block_norm(block), 0)
    }

    fn prox(&self, block: &mut [f64], step: f64, _b: usize) {
        radial_prox(block, step, |t, s| self.inner.prox(t, s, 0));
    }

    fn subdiff_distance(&self, block: &[f64], grad_block: &[f64], _b: usize) -> f64 {
        let (lam, gam) = (self.inner.lambda, self.inner.gamma);
        let t = block_norm(block);
        if t == 0.0 {
            (block_norm(grad_block) - lam).max(0.0)
        } else if t <= lam {
            radial_dist(block, grad_block, lam)
        } else if t <= gam * lam {
            radial_dist(block, grad_block, (gam * lam - t) / (gam - 1.0))
        } else {
            block_norm(grad_block)
        }
    }

    fn is_convex(&self) -> bool {
        false
    }

    fn validate_step(&self, step: f64) {
        self.inner.validate_step(step);
    }

    fn name(&self) -> &'static str {
        "block_scad"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force 2-D check of Prop 18: prox minimises
    /// ½‖x−v‖² + step φ(‖x‖) over a polar grid.
    fn assert_block_prox_minimizes<B: BlockPenalty>(pen: &B, v: &[f64; 2], step: f64, tol: f64) {
        let mut x_star = *v;
        pen.prox(&mut x_star, step, 0);
        let obj = |x: &[f64; 2]| {
            let d0 = x[0] - v[0];
            let d1 = x[1] - v[1];
            0.5 * (d0 * d0 + d1 * d1) + step * pen.value(x, 0)
        };
        let o_star = obj(&x_star);
        let vmax = (v[0] * v[0] + v[1] * v[1]).sqrt() * 2.0 + 2.0;
        let mut r = 0.0;
        while r <= vmax {
            for k in 0..64 {
                let th = 2.0 * std::f64::consts::PI * k as f64 / 64.0;
                let x = [r * th.cos(), r * th.sin()];
                assert!(
                    o_star <= obj(&x) + tol,
                    "{}: prox({v:?})={x_star:?} obj {o_star} beaten at {x:?} obj {}",
                    pen.name(),
                    obj(&x)
                );
            }
            r += vmax / 300.0;
        }
    }

    #[test]
    fn l21_prox_is_group_soft_threshold() {
        let p = BlockL21::new(1.0);
        let mut row = [3.0, 4.0]; // norm 5
        p.prox(&mut row, 1.0, 0);
        // scale (1 - 1/5) = 0.8
        assert!((row[0] - 2.4).abs() < 1e-14);
        assert!((row[1] - 3.2).abs() < 1e-14);
        let mut small = [0.3, 0.4];
        p.prox(&mut small, 1.0, 0);
        assert_eq!(small, [0.0, 0.0]);
    }

    #[test]
    fn block_proxes_minimize_objective() {
        assert_block_prox_minimizes(&BlockL21::new(0.8), &[1.5, -0.7], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockMcp::new(0.8, 3.0), &[1.5, -0.7], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockMcp::new(0.8, 3.0), &[4.0, 1.0], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockScad::new(0.8, 3.7), &[1.5, -0.7], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockScad::new(0.8, 3.7), &[4.0, 1.0], 1.0, 1e-3);
        assert_block_prox_minimizes(
            &WeightedGroupLasso::new(0.8, vec![1.3]),
            &[1.5, -0.7],
            1.0,
            1e-3,
        );
    }

    #[test]
    fn block_mcp_is_unbiased_for_large_rows() {
        let p = BlockMcp::new(1.0, 3.0);
        let mut row = [10.0, 0.0];
        p.prox(&mut row, 1.0, 0);
        assert_eq!(row, [10.0, 0.0], "large rows must pass through un-shrunk");
        // while l21 shrinks them (the Figure-4 amplitude bias)
        let l21 = BlockL21::new(1.0);
        let mut row2 = [10.0, 0.0];
        l21.prox(&mut row2, 1.0, 0);
        assert!(row2[0] < 10.0);
    }

    #[test]
    fn subdiff_distance_zero_at_block_kkt() {
        let p = BlockL21::new(1.0);
        // row 0, small gradient: inside the ball
        assert_eq!(p.subdiff_distance(&[0.0, 0.0], &[0.3, 0.4], 0), 0.0);
        // row != 0: grad must be −λ row/‖row‖
        let row = [3.0, 4.0];
        let grad = [-0.6, -0.8];
        assert!(p.subdiff_distance(&row, &grad, 0) < 1e-14);
    }

    #[test]
    fn gsupp_is_nonzero_rows() {
        let p = BlockMcp::new(1.0, 3.0);
        assert!(!p.in_gsupp(&[0.0, 0.0]));
        assert!(p.in_gsupp(&[0.0, 0.1]));
    }

    #[test]
    fn weighted_group_lasso_scales_per_block() {
        let part = BlockPartition::contiguous(&[4, 1]);
        let p = WeightedGroupLasso::sqrt_sizes(1.0, &part);
        assert_eq!(p.weights(), &[2.0, 1.0]);
        assert_eq!(p.block_weight(0), 2.0);
        // block 0 (weight 2): prox threshold is 2λ
        let mut b0 = [1.5, 0.0, 0.0, 0.0];
        p.prox(&mut b0, 1.0, 0);
        assert_eq!(b0, [0.0; 4], "norm 1.5 < weight 2 must vanish");
        // block 1 (weight 1): same input survives
        let mut b1 = [1.5];
        p.prox(&mut b1, 1.0, 1);
        assert!((b1[0] - 0.5).abs() < 1e-14);
        // value and subdiff honour the weight
        assert!((p.value(&[0.0, 3.0, 0.0, 4.0], 0) - 10.0).abs() < 1e-14);
        assert_eq!(p.subdiff_distance(&[0.0; 4], &[0.0, 1.9, 0.0, 0.0], 0), 0.0);
        assert!(p.subdiff_distance(&[0.0], &[1.9], 1) > 0.0);
    }

    #[test]
    fn trivial_partition_block_prox_equals_scalar_prox() {
        // a size-1 block reduces every block penalty to its scalar twin
        use crate::penalty::{soft_threshold, Penalty};
        for &v in &[-2.5, -0.4, 0.0, 0.7, 3.0] {
            for &step in &[0.5, 1.0, 2.0] {
                let mut b = [v];
                BlockL21::new(1.0).prox(&mut b, step, 0);
                assert!((b[0] - soft_threshold(v, step)).abs() < 1e-14);
                let mut m = [v];
                BlockMcp::new(0.8, 3.0).prox(&mut m, step, 0);
                let scalar = Mcp::new(0.8, 3.0).prox(v, step, 0);
                assert!((m[0] - scalar).abs() < 1e-14, "mcp {v} {step}: {} vs {scalar}", m[0]);
                let mut s = [v];
                BlockScad::new(0.8, 3.7).prox(&mut s, step, 0);
                let scalar = Scad::new(0.8, 3.7).prox(v, step, 0);
                assert!((s[0] - scalar).abs() < 1e-14, "scad {v} {step}: {} vs {scalar}", s[0]);
            }
        }
    }
}
