//! Block (row-wise) penalties for the multitask setting (paper Appendix D):
//! `g(W) = Σ_j φ(‖W_{j,:}‖)` with φ an even 1-D penalty. By Proposition 18,
//!
//! ```text
//! prox_{φ(‖·‖)}(x) = prox_φ(‖x‖) · x / ‖x‖ ,
//! ```
//!
//! so each block penalty delegates to its scalar counterpart on the row
//! norm. Block-ℓ2,1 is the convex baseline of Figure 4; block-MCP and
//! block-SCAD are the non-convex penalties that recover both auditory
//! sources.

use super::{Mcp, Penalty, Scad};

/// A row-separable penalty on `W ∈ R^{p×T}`.
pub trait BlockPenalty: Clone + Send + Sync {
    /// `φ(‖row‖)`.
    fn value(&self, row: &[f64]) -> f64;

    /// In-place `row ← prox_{step·φ(‖·‖)}(row)`.
    fn prox(&self, row: &mut [f64], step: f64);

    /// `dist(−∇_{j,:} f, ∂g_j(row))` for the working-set score.
    fn subdiff_distance(&self, row: &[f64], grad_row: &[f64]) -> f64;

    /// Generalized support membership for the row.
    fn in_gsupp(&self, row: &[f64]) -> bool {
        row.iter().any(|&v| v != 0.0)
    }

    fn is_convex(&self) -> bool;

    fn name(&self) -> &'static str;
}

#[inline]
fn row_norm(row: &[f64]) -> f64 {
    crate::linalg::nrm2(row)
}

/// Apply Proposition 18 given the scalar prox of φ.
#[inline]
fn radial_prox(row: &mut [f64], step: f64, scalar_prox: impl Fn(f64, f64) -> f64) {
    let t = row_norm(row);
    if t == 0.0 {
        return;
    }
    let scale = scalar_prox(t, step) / t;
    for v in row.iter_mut() {
        *v *= scale;
    }
}

/// ‖grad + dir_scale · row/‖row‖‖ — distance for a differentiable-radial φ.
#[inline]
fn radial_dist(row: &[f64], grad_row: &[f64], dir_scale: f64) -> f64 {
    let t = row_norm(row);
    let mut s = 0.0;
    for (&g, &r) in grad_row.iter().zip(row.iter()) {
        let d = g + dir_scale * r / t;
        s += d * d;
    }
    s.sqrt()
}

// ---------------------------------------------------------------- ℓ2,1 --

/// `g(W) = λ Σ_j ‖W_{j,:}‖` — multitask Lasso / group penalty.
#[derive(Clone, Debug)]
pub struct BlockL21 {
    pub lambda: f64,
}

impl BlockL21 {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda >= 0.0);
        Self { lambda }
    }
}

impl BlockPenalty for BlockL21 {
    fn value(&self, row: &[f64]) -> f64 {
        self.lambda * row_norm(row)
    }

    fn prox(&self, row: &mut [f64], step: f64) {
        let t = row_norm(row);
        if t == 0.0 {
            return;
        }
        let scale = (1.0 - step * self.lambda / t).max(0.0);
        for v in row.iter_mut() {
            *v *= scale;
        }
    }

    fn subdiff_distance(&self, row: &[f64], grad_row: &[f64]) -> f64 {
        let t = row_norm(row);
        if t == 0.0 {
            // ∂ at 0 = λ·unit ball: dist = max(0, ‖grad‖ − λ)
            (row_norm(grad_row) - self.lambda).max(0.0)
        } else {
            radial_dist(row, grad_row, self.lambda)
        }
    }

    fn is_convex(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "block_l21"
    }
}

// ------------------------------------------------------------ block MCP --

/// `g(W) = Σ_j MCP_{λ,γ}(‖W_{j,:}‖)`.
#[derive(Clone, Debug)]
pub struct BlockMcp {
    inner: Mcp,
}

impl BlockMcp {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { inner: Mcp::new(lambda, gamma) }
    }
}

impl BlockPenalty for BlockMcp {
    fn value(&self, row: &[f64]) -> f64 {
        self.inner.value(row_norm(row), 0)
    }

    fn prox(&self, row: &mut [f64], step: f64) {
        radial_prox(row, step, |t, s| self.inner.prox(t, s, 0));
    }

    fn subdiff_distance(&self, row: &[f64], grad_row: &[f64]) -> f64 {
        let (lam, gam) = (self.inner.lambda, self.inner.gamma);
        let t = row_norm(row);
        if t == 0.0 {
            (row_norm(grad_row) - lam).max(0.0)
        } else if t < gam * lam {
            // MCP'(t) = λ − t/γ
            radial_dist(row, grad_row, lam - t / gam)
        } else {
            row_norm(grad_row)
        }
    }

    fn is_convex(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "block_mcp"
    }
}

// ----------------------------------------------------------- block SCAD --

/// `g(W) = Σ_j SCAD_{λ,γ}(‖W_{j,:}‖)`.
#[derive(Clone, Debug)]
pub struct BlockScad {
    inner: Scad,
}

impl BlockScad {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        Self { inner: Scad::new(lambda, gamma) }
    }
}

impl BlockPenalty for BlockScad {
    fn value(&self, row: &[f64]) -> f64 {
        self.inner.value(row_norm(row), 0)
    }

    fn prox(&self, row: &mut [f64], step: f64) {
        radial_prox(row, step, |t, s| self.inner.prox(t, s, 0));
    }

    fn subdiff_distance(&self, row: &[f64], grad_row: &[f64]) -> f64 {
        let (lam, gam) = (self.inner.lambda, self.inner.gamma);
        let t = row_norm(row);
        if t == 0.0 {
            (row_norm(grad_row) - lam).max(0.0)
        } else if t <= lam {
            radial_dist(row, grad_row, lam)
        } else if t <= gam * lam {
            radial_dist(row, grad_row, (gam * lam - t) / (gam - 1.0))
        } else {
            row_norm(grad_row)
        }
    }

    fn is_convex(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "block_scad"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force 2-D check of Prop 18: prox minimises
    /// ½‖x−v‖² + step φ(‖x‖) over a polar grid.
    fn assert_block_prox_minimizes<B: BlockPenalty>(pen: &B, v: &[f64; 2], step: f64, tol: f64) {
        let mut x_star = *v;
        pen.prox(&mut x_star, step);
        let obj = |x: &[f64; 2]| {
            let d0 = x[0] - v[0];
            let d1 = x[1] - v[1];
            0.5 * (d0 * d0 + d1 * d1) + step * pen.value(x)
        };
        let o_star = obj(&x_star);
        let vmax = (v[0] * v[0] + v[1] * v[1]).sqrt() * 2.0 + 2.0;
        let mut r = 0.0;
        while r <= vmax {
            for k in 0..64 {
                let th = 2.0 * std::f64::consts::PI * k as f64 / 64.0;
                let x = [r * th.cos(), r * th.sin()];
                assert!(
                    o_star <= obj(&x) + tol,
                    "{}: prox({v:?})={x_star:?} obj {o_star} beaten at {x:?} obj {}",
                    pen.name(),
                    obj(&x)
                );
            }
            r += vmax / 300.0;
        }
    }

    #[test]
    fn l21_prox_is_group_soft_threshold() {
        let p = BlockL21::new(1.0);
        let mut row = [3.0, 4.0]; // norm 5
        p.prox(&mut row, 1.0);
        // scale (1 - 1/5) = 0.8
        assert!((row[0] - 2.4).abs() < 1e-14);
        assert!((row[1] - 3.2).abs() < 1e-14);
        let mut small = [0.3, 0.4];
        p.prox(&mut small, 1.0);
        assert_eq!(small, [0.0, 0.0]);
    }

    #[test]
    fn block_proxes_minimize_objective() {
        assert_block_prox_minimizes(&BlockL21::new(0.8), &[1.5, -0.7], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockMcp::new(0.8, 3.0), &[1.5, -0.7], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockMcp::new(0.8, 3.0), &[4.0, 1.0], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockScad::new(0.8, 3.7), &[1.5, -0.7], 1.0, 1e-3);
        assert_block_prox_minimizes(&BlockScad::new(0.8, 3.7), &[4.0, 1.0], 1.0, 1e-3);
    }

    #[test]
    fn block_mcp_is_unbiased_for_large_rows() {
        let p = BlockMcp::new(1.0, 3.0);
        let mut row = [10.0, 0.0];
        p.prox(&mut row, 1.0);
        assert_eq!(row, [10.0, 0.0], "large rows must pass through un-shrunk");
        // while l21 shrinks them (the Figure-4 amplitude bias)
        let l21 = BlockL21::new(1.0);
        let mut row2 = [10.0, 0.0];
        l21.prox(&mut row2, 1.0);
        assert!(row2[0] < 10.0);
    }

    #[test]
    fn subdiff_distance_zero_at_block_kkt() {
        let p = BlockL21::new(1.0);
        // row 0, small gradient: inside the ball
        assert_eq!(p.subdiff_distance(&[0.0, 0.0], &[0.3, 0.4]), 0.0);
        // row != 0: grad must be −λ row/‖row‖
        let row = [3.0, 4.0];
        let grad = [-0.6, -0.8];
        assert!(p.subdiff_distance(&row, &grad) < 1e-14);
    }

    #[test]
    fn gsupp_is_nonzero_rows() {
        let p = BlockMcp::new(1.0, 3.0);
        assert!(!p.in_gsupp(&[0.0, 0.0]));
        assert!(p.in_gsupp(&[0.0, 0.1]));
    }
}
