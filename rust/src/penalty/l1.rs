//! ℓ1 penalty `g_j(x) = λ|x|` — the Lasso.

use super::{soft_threshold, Penalty};

/// The Lasso penalty `λ‖β‖₁`; its prox is soft-thresholding.
///
/// # Examples
///
/// ```
/// use skglm::penalty::{Penalty, L1};
///
/// let pen = L1::new(0.5);
/// // prox_{step·g}(v) = ST(v, step·λ)
/// assert_eq!(pen.prox(2.0, 1.0, 0), 1.5);
/// assert_eq!(pen.prox(-0.3, 1.0, 0), 0.0);
/// // at β=0 the subdifferential is [−λ, λ]: optimal while |∇_j f| ≤ λ
/// assert_eq!(pen.subdiff_distance(0.0, 0.4, 0), 0.0);
/// assert!(pen.is_convex());
/// ```
#[derive(Clone, Debug)]
pub struct L1 {
    pub lambda: f64,
}

impl L1 {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda >= 0.0, "lambda must be non-negative");
        Self { lambda }
    }
}

impl Penalty for L1 {
    #[inline]
    fn value(&self, beta_j: f64, _j: usize) -> f64 {
        self.lambda * beta_j.abs()
    }

    #[inline]
    fn prox(&self, v: f64, step: f64, _j: usize) -> f64 {
        soft_threshold(v, step * self.lambda)
    }

    #[inline]
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, _j: usize) -> f64 {
        if beta_j == 0.0 {
            // ∂g(0) = [−λ, λ]: dist(−grad, [−λ,λ]) = max(0, |grad| − λ)
            (grad_j.abs() - self.lambda).max(0.0)
        } else {
            // ∂g(β) = {λ sign β}: |−grad − λ sign β|
            (grad_j + self.lambda * beta_j.signum()).abs()
        }
    }

    #[inline]
    fn in_gsupp(&self, beta_j: f64) -> bool {
        beta_j != 0.0
    }

    fn is_convex(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "l1"
    }

    fn as_batchable(&self) -> Option<super::BatchPenalty> {
        Some(super::BatchPenalty::L1(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::penalty::test_helpers::assert_prox_is_minimizer;

    #[test]
    fn prox_is_soft_threshold() {
        let p = L1::new(1.0);
        assert_eq!(p.prox(3.0, 0.5, 0), 2.5);
        assert_eq!(p.prox(-0.4, 0.5, 0), 0.0);
    }

    #[test]
    fn prox_minimizes_objective() {
        let p = L1::new(0.7);
        for &v in &[-3.0, -0.5, 0.0, 0.2, 1.0, 5.0] {
            for &step in &[0.1, 1.0, 2.5] {
                assert_prox_is_minimizer(&p, v, step, 1e-6);
            }
        }
    }

    #[test]
    fn subdiff_distance_zero_iff_kkt() {
        let p = L1::new(1.0);
        // at 0 with |grad| <= lambda: optimal
        assert_eq!(p.subdiff_distance(0.0, 0.5, 0), 0.0);
        assert_eq!(p.subdiff_distance(0.0, -1.0, 0), 0.0);
        assert!((p.subdiff_distance(0.0, 1.5, 0) - 0.5).abs() < 1e-15);
        // at β>0: grad must equal −λ
        assert_eq!(p.subdiff_distance(2.0, -1.0, 0), 0.0);
        assert!((p.subdiff_distance(2.0, 0.0, 0) - 1.0).abs() < 1e-15);
        // at β<0: grad must equal +λ
        assert_eq!(p.subdiff_distance(-2.0, 1.0, 0), 0.0);
    }

    #[test]
    fn gsupp_is_nonzero_set() {
        let p = L1::new(1.0);
        assert!(!p.in_gsupp(0.0));
        assert!(p.in_gsupp(0.1));
        assert!(p.in_gsupp(-3.0));
    }

    #[test]
    fn value_sum() {
        let p = L1::new(2.0);
        assert_eq!(p.value_sum(&[1.0, -2.0, 0.0]), 6.0);
    }
}
