//! SCAD penalty (Fan & Li 2001; grouped with MCP in the paper as the
//! α-semi-convex non-convex class, valid for γ L_j > 1 + ... — here the
//! prox closed form requires `γ > 1 + step`).
//!
//! ```text
//! SCAD_{λ,γ}(x) = λ|x|                          if |x| ≤ λ
//!               = (2γλ|x| − x² − λ²)/(2(γ−1))   if λ < |x| ≤ γλ
//!               = λ²(γ+1)/2                     if |x| > γλ
//! ```

use super::{soft_threshold, Penalty};

/// The SCAD penalty (three-region prox, unbiased for large coefficients).
///
/// # Examples
///
/// ```
/// use skglm::penalty::{Penalty, Scad};
///
/// let pen = Scad::new(1.0, 3.7); // λ = 1, γ = 3.7 (literature default)
/// // the penalty is λ|x| near zero and saturates at λ²(γ+1)/2
/// assert_eq!(pen.value(0.5, 0), 0.5);
/// assert_eq!(pen.value(10.0, 0), 2.35);
/// // coefficients beyond γλ are not shrunk at all
/// assert_eq!(pen.prox(9.0, 1.0, 0), 9.0);
/// assert!(!pen.is_convex());
/// ```
#[derive(Clone, Debug)]
pub struct Scad {
    pub lambda: f64,
    pub gamma: f64,
}

impl Scad {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        assert!(lambda >= 0.0);
        assert!(gamma > 2.0, "SCAD needs gamma > 2 (literature default 3.7)");
        Self { lambda, gamma }
    }
}

impl Penalty for Scad {
    #[inline]
    fn value(&self, beta_j: f64, _j: usize) -> f64 {
        let (l, g) = (self.lambda, self.gamma);
        let a = beta_j.abs();
        if a <= l {
            l * a
        } else if a <= g * l {
            (2.0 * g * l * a - a * a - l * l) / (2.0 * (g - 1.0))
        } else {
            l * l * (g + 1.0) / 2.0
        }
    }

    /// Three-region prox; requires `γ > 1 + step`.
    #[inline]
    fn prox(&self, v: f64, step: f64, _j: usize) -> f64 {
        let (l, g) = (self.lambda, self.gamma);
        debug_assert!(
            g > 1.0 + step,
            "SCAD prox outside semi-convex regime: gamma={g} <= 1 + step={step}"
        );
        let a = v.abs();
        if a <= l * (1.0 + step) {
            soft_threshold(v, step * l)
        } else if a <= g * l {
            ((g - 1.0) * v - v.signum() * step * g * l) / (g - 1.0 - step)
        } else {
            v
        }
    }

    #[inline]
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, _j: usize) -> f64 {
        let (l, g) = (self.lambda, self.gamma);
        let a = beta_j.abs();
        if beta_j == 0.0 {
            (grad_j.abs() - l).max(0.0)
        } else if a <= l {
            (grad_j + l * beta_j.signum()).abs()
        } else if a <= g * l {
            (grad_j + beta_j.signum() * (g * l - a) / (g - 1.0)).abs()
        } else {
            grad_j.abs()
        }
    }

    #[inline]
    fn in_gsupp(&self, beta_j: f64) -> bool {
        beta_j != 0.0
    }

    fn is_convex(&self) -> bool {
        false
    }

    fn validate_step(&self, step: f64) {
        assert!(
            self.gamma > 1.0 + step,
            "SCAD with gamma={} is not alpha-semi-convex for step {step}; \
             normalise columns or increase gamma",
            self.gamma
        );
    }

    fn name(&self) -> &'static str {
        "scad"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::penalty::test_helpers::assert_prox_is_minimizer;

    #[test]
    fn value_regions_and_continuity() {
        let p = Scad::new(1.0, 3.7);
        assert_eq!(p.value(0.0, 0), 0.0);
        assert_eq!(p.value(0.5, 0), 0.5);
        // continuity at |x| = λ and |x| = γλ
        for &knee in &[1.0, 3.7] {
            let lo = p.value(knee - 1e-9, 0);
            let hi = p.value(knee + 1e-9, 0);
            assert!((lo - hi).abs() < 1e-7, "jump at {knee}");
        }
        // constant tail
        assert_eq!(p.value(10.0, 0), p.value(-50.0, 0));
        assert!((p.value(10.0, 0) - 4.7 / 2.0).abs() < 1e-15);
    }

    #[test]
    fn prox_is_identity_for_large_inputs() {
        let p = Scad::new(1.0, 3.7);
        assert_eq!(p.prox(5.0, 1.0, 0), 5.0);
        assert_eq!(p.prox(-5.0, 1.0, 0), -5.0);
    }

    #[test]
    fn prox_soft_thresholds_small_inputs() {
        let p = Scad::new(1.0, 3.7);
        assert_eq!(p.prox(1.5, 1.0, 0), 0.5);
        assert_eq!(p.prox(0.9, 1.0, 0), 0.0);
    }

    #[test]
    fn prox_continuous_at_region_boundaries() {
        let p = Scad::new(1.0, 3.7);
        let step = 0.9;
        for &v in &[1.0 * (1.0 + step), 3.7] {
            let lo = p.prox(v - 1e-9, step, 0);
            let hi = p.prox(v + 1e-9, step, 0);
            assert!((lo - hi).abs() < 1e-6, "jump at {v}: {lo} vs {hi}");
        }
    }

    #[test]
    fn prox_minimizes_objective_in_semiconvex_regime() {
        let p = Scad::new(0.8, 3.7);
        for &v in &[-6.0, -2.0, -0.5, 0.0, 0.7, 1.8, 3.0, 8.0] {
            for &step in &[0.4, 1.0, 2.0] {
                assert_prox_is_minimizer(&p, v, step, 1e-5);
            }
        }
    }

    #[test]
    fn subdiff_distance_zero_at_prox_fixed_points() {
        let p = Scad::new(1.0, 3.7);
        let step = 0.5;
        for &v in &[-4.0, -1.2, 0.3, 2.2, 6.0] {
            let beta = p.prox(v, step, 0);
            // prox optimality: (v − β)/step ∈ ∂g(β), i.e. β is a critical
            // point of f + g when ∇f(β) = (β − v)/step
            let grad = (beta - v) / step;
            assert!(
                p.subdiff_distance(beta, grad, 0) < 1e-10,
                "v={v}, beta={beta}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "gamma > 2")]
    fn constructor_rejects_small_gamma() {
        Scad::new(1.0, 1.5);
    }
}
