//! Weighted ℓ1 penalty `g_j(x) = λ w_j |x|` with `w_j ≥ 0` (possibly 0) —
//! the inner penalty of the iteratively-reweighted-ℓ1 MCP baseline
//! (Candès et al. 2008; paper §3.2: "this approach requires solving
//! weighted Lassos with some 0 weights", which skglm's generic design —
//! and ours — handles natively).

use super::{soft_threshold, Penalty};

#[derive(Clone, Debug)]
pub struct WeightedL1 {
    pub lambda: f64,
    pub weights: Vec<f64>,
}

impl WeightedL1 {
    pub fn new(lambda: f64, weights: Vec<f64>) -> Self {
        assert!(lambda >= 0.0);
        assert!(weights.iter().all(|&w| w >= 0.0), "weights must be non-negative");
        Self { lambda, weights }
    }
}

impl Penalty for WeightedL1 {
    #[inline]
    fn value(&self, beta_j: f64, j: usize) -> f64 {
        self.lambda * self.weights[j] * beta_j.abs()
    }

    #[inline]
    fn prox(&self, v: f64, step: f64, j: usize) -> f64 {
        soft_threshold(v, step * self.lambda * self.weights[j])
    }

    #[inline]
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, j: usize) -> f64 {
        let lw = self.lambda * self.weights[j];
        if beta_j == 0.0 {
            (grad_j.abs() - lw).max(0.0)
        } else {
            (grad_j + lw * beta_j.signum()).abs()
        }
    }

    #[inline]
    fn in_gsupp(&self, beta_j: f64) -> bool {
        beta_j != 0.0
    }

    fn is_convex(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "weighted_l1"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_weight_features_are_unpenalized() {
        let p = WeightedL1::new(1.0, vec![0.0, 1.0]);
        // weight 0: prox is identity, any nonzero is in the "support", and
        // optimality demands grad = 0
        assert_eq!(p.prox(0.3, 1.0, 0), 0.3);
        assert_eq!(p.subdiff_distance(0.0, 0.4, 0), 0.4);
        // weight 1: classic lasso behaviour
        assert_eq!(p.prox(0.3, 1.0, 1), 0.0);
        assert_eq!(p.subdiff_distance(0.0, 0.4, 1), 0.0);
    }

    #[test]
    fn matches_plain_l1_with_unit_weights() {
        let w = WeightedL1::new(0.9, vec![1.0; 4]);
        let l1 = crate::penalty::L1::new(0.9);
        for &v in &[-2.0, 0.1, 3.0] {
            assert_eq!(w.prox(v, 0.7, 2), l1.prox(v, 0.7, 2));
            assert_eq!(w.value(v, 3), l1.value(v, 3));
            assert_eq!(w.subdiff_distance(v, 0.2, 1), l1.subdiff_distance(v, 0.2, 1));
        }
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_weights() {
        WeightedL1::new(1.0, vec![-0.1]);
    }
}
