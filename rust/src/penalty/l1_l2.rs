//! Elastic-net penalty `g_j(x) = λ(ρ|x| + (1−ρ)x²/2)` (paper §3.1).

use super::{soft_threshold, Penalty};

#[derive(Clone, Debug)]
pub struct L1L2 {
    pub lambda: f64,
    /// ℓ1 ratio ρ ∈ [0, 1] (paper uses ρ = 0.5).
    pub rho: f64,
}

impl L1L2 {
    pub fn new(lambda: f64, rho: f64) -> Self {
        assert!(lambda >= 0.0);
        assert!((0.0..=1.0).contains(&rho), "l1 ratio must be in [0,1]");
        Self { lambda, rho }
    }
}

impl Penalty for L1L2 {
    #[inline]
    fn value(&self, beta_j: f64, _j: usize) -> f64 {
        self.lambda * (self.rho * beta_j.abs() + 0.5 * (1.0 - self.rho) * beta_j * beta_j)
    }

    #[inline]
    fn prox(&self, v: f64, step: f64, _j: usize) -> f64 {
        // argmin ½(x−v)² + step λρ|x| + step λ(1−ρ)x²/2
        soft_threshold(v, step * self.lambda * self.rho)
            / (1.0 + step * self.lambda * (1.0 - self.rho))
    }

    #[inline]
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, _j: usize) -> f64 {
        let l1 = self.lambda * self.rho;
        let l2 = self.lambda * (1.0 - self.rho);
        if beta_j == 0.0 {
            (grad_j.abs() - l1).max(0.0)
        } else {
            (grad_j + l1 * beta_j.signum() + l2 * beta_j).abs()
        }
    }

    #[inline]
    fn in_gsupp(&self, beta_j: f64) -> bool {
        // differentiable away from 0 (quadratic part is smooth everywhere)
        beta_j != 0.0 || self.rho == 0.0
    }

    fn is_convex(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "l1_l2"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::penalty::test_helpers::assert_prox_is_minimizer;

    #[test]
    fn reduces_to_l1_when_rho_1() {
        let enet = L1L2::new(1.3, 1.0);
        let l1 = crate::penalty::L1::new(1.3);
        for &v in &[-2.0, 0.3, 4.0] {
            assert_eq!(enet.prox(v, 0.7, 0), l1.prox(v, 0.7, 0));
            assert_eq!(enet.value(v, 0), l1.value(v, 0));
        }
    }

    #[test]
    fn reduces_to_ridge_when_rho_0() {
        let ridge = L1L2::new(2.0, 0.0);
        // prox of ridge: v / (1 + step λ)
        assert!((ridge.prox(3.0, 0.5, 0) - 3.0 / 2.0).abs() < 1e-15);
        assert!(ridge.in_gsupp(0.0), "ridge is smooth at 0");
    }

    #[test]
    fn prox_minimizes_objective() {
        let p = L1L2::new(0.9, 0.5);
        for &v in &[-3.0, -0.2, 0.0, 0.4, 2.0] {
            for &step in &[0.2, 1.0, 3.0] {
                assert_prox_is_minimizer(&p, v, step, 1e-6);
            }
        }
    }

    #[test]
    fn subdiff_distance_consistent_with_prox_fixed_point() {
        // score == 0 at a point iff it is a fixed point of the prox map
        let p = L1L2::new(1.0, 0.5);
        let step = 0.7;
        for &beta in &[-1.5f64, 0.0, 0.8] {
            // choose grad so that beta is a fixed point: beta = prox(beta - step*grad)
            // for beta != 0: grad = -(l1 sign + l2 beta); at 0: any |grad| <= l1
            let (l1, l2) = (0.5, 0.5);
            let grad = if beta == 0.0 { 0.3 } else { -(l1 * beta.signum() + l2 * beta) };
            assert!(p.subdiff_distance(beta, grad, 0) < 1e-12);
            let fp = p.prox(beta - step * grad, step, 0);
            assert!((fp - beta).abs() < 1e-12);
        }
    }
}
