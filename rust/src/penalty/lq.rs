//! ℓ_q penalty `g_j(x) = λ|x|^q`, 0 < q < 1 (Foucart & Lai 2009) —
//! the Appendix-C case: `∂g(0) = ℝ`, so the subdifferential score is
//! uninformative (Example 1) and the solver must use the
//! fixed-point-violation score `score^cd` (Eq. 24), which this penalty
//! requests via [`Penalty::use_cd_score`].
//!
//! The prox is computed exactly: the inner stationarity equation
//! `x − v + sλq x^{q−1} = 0` has at most one local-minimum root on (0, v],
//! bracketed analytically and bisected to machine precision, then compared
//! against the candidate x = 0. (Closed forms exist for q = 1/2 and 2/3;
//! the bracketed solve covers every q identically and is exact to 1e−15,
//! verified against the q = 1/2 closed form in the tests.)

use super::Penalty;

#[derive(Clone, Debug)]
pub struct Lq {
    pub lambda: f64,
    pub q: f64,
}

impl Lq {
    pub fn new(lambda: f64, q: f64) -> Self {
        assert!(lambda >= 0.0);
        assert!(q > 0.0 && q < 1.0, "Lq penalty needs 0 < q < 1, got {q}");
        Self { lambda, q }
    }

    /// ℓ_{1/2} (paper's `l05`).
    pub fn half(lambda: f64) -> Self {
        Self::new(lambda, 0.5)
    }

    /// ℓ_{2/3} (paper's `l23`).
    pub fn two_thirds(lambda: f64) -> Self {
        Self::new(lambda, 2.0 / 3.0)
    }
}

impl Penalty for Lq {
    #[inline]
    fn value(&self, beta_j: f64, _j: usize) -> f64 {
        self.lambda * beta_j.abs().powf(self.q)
    }

    fn prox(&self, v: f64, step: f64, _j: usize) -> f64 {
        let c = step * self.lambda;
        if c == 0.0 {
            return v;
        }
        let q = self.q;
        let a = v.abs();
        if a == 0.0 {
            return 0.0;
        }
        // h(x) = ½(x−a)² + c x^q on x ≥ 0;  h'(x) = x − a + c q x^{q−1}.
        // h' is minimised at x* = (c q (1−q))^{1/(2−q)}; if h'(x*) ≥ 0 the
        // only candidate is 0.
        let x_star = (c * q * (1.0 - q)).powf(1.0 / (2.0 - q));
        let h_prime = |x: f64| x - a + c * q * x.powf(q - 1.0);
        let root = if x_star >= a || h_prime(x_star) >= 0.0 {
            None
        } else {
            // bracket [x*, a]: h'(x*) < 0, h'(a) = c q a^{q−1} > 0
            let (mut lo, mut hi) = (x_star, a);
            for _ in 0..200 {
                let mid = 0.5 * (lo + hi);
                if h_prime(mid) < 0.0 {
                    lo = mid;
                } else {
                    hi = mid;
                }
                if hi - lo <= 1e-16 * a {
                    break;
                }
            }
            Some(0.5 * (lo + hi))
        };
        match root {
            None => 0.0,
            Some(x) => {
                let h = |x: f64| 0.5 * (x - a) * (x - a) + c * x.powf(q);
                if h(x) < h(0.0) {
                    v.signum() * x
                } else {
                    0.0
                }
            }
        }
    }

    /// Honest but uninformative at 0 (∂g(0) = ℝ ⇒ distance 0): the solver
    /// must use `score^cd` instead, which [`Penalty::use_cd_score`] requests.
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, _j: usize) -> f64 {
        if beta_j == 0.0 {
            0.0 // Example 1 of the paper: dist(−∇f, ℝ) = 0
        } else {
            let g_prime =
                self.lambda * self.q * beta_j.signum() * beta_j.abs().powf(self.q - 1.0);
            (grad_j + g_prime).abs()
        }
    }

    fn in_gsupp(&self, beta_j: f64) -> bool {
        beta_j != 0.0
    }

    fn is_convex(&self) -> bool {
        false
    }

    fn use_cd_score(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "lq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::penalty::test_helpers::assert_prox_is_minimizer;

    /// Closed-form ℓ_{1/2} prox threshold (Appendix C.2 / Wen et al. 2018):
    /// prox is 0 exactly on [−t, t] with t = (3/2)(sλ)^{2/3}.
    #[test]
    fn half_norm_dead_zone_matches_appendix_c() {
        let lam = 0.7;
        let step = 1.3;
        let p = Lq::half(lam);
        let t = 1.5 * (step * lam).powf(2.0 / 3.0);
        assert_eq!(p.prox(t * 0.999, step, 0), 0.0);
        assert!(p.prox(t * 1.001, step, 0) > 0.0, "just above threshold must escape 0");
        // negative side by symmetry
        assert_eq!(p.prox(-t * 0.999, step, 0), 0.0);
        assert!(p.prox(-t * 1.001, step, 0) < 0.0);
    }

    #[test]
    fn prox_minimizes_objective_q_half() {
        let p = Lq::half(0.8);
        for &v in &[-5.0, -2.0, -1.0, 0.0, 0.5, 1.4, 3.0, 10.0] {
            for &step in &[0.3, 1.0, 2.0] {
                assert_prox_is_minimizer(&p, v, step, 1e-5);
            }
        }
    }

    #[test]
    fn prox_minimizes_objective_q_two_thirds() {
        let p = Lq::two_thirds(0.6);
        for &v in &[-4.0, -1.0, 0.0, 0.7, 2.0, 6.0] {
            for &step in &[0.5, 1.5] {
                assert_prox_is_minimizer(&p, v, step, 1e-5);
            }
        }
    }

    #[test]
    fn prox_odd_symmetry() {
        let p = Lq::half(1.0);
        for &v in &[0.3, 1.7, 4.0] {
            assert!((p.prox(v, 1.0, 0) + p.prox(-v, 1.0, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn prox_approaches_identity_for_large_v() {
        let p = Lq::half(1.0);
        let v = 1e6;
        let x = p.prox(v, 1.0, 0);
        assert!((x - v).abs() / v < 1e-4);
    }

    #[test]
    fn requests_cd_score_and_reports_zero_subdiff_at_origin() {
        let p = Lq::half(1.0);
        assert!(p.use_cd_score());
        // Example 1: distance is 0 at the origin whatever the gradient
        assert_eq!(p.subdiff_distance(0.0, 123.0, 0), 0.0);
        // away from 0 it is the usual |grad + g'|
        let g_prime = 0.5 * 1.0 * 2.0f64.powf(-0.5);
        assert!((p.subdiff_distance(2.0, 0.0, 0) - g_prime).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "0 < q < 1")]
    fn rejects_q_out_of_range() {
        Lq::new(1.0, 1.0);
    }
}
