//! Minimax Concave Penalty (MCP, Zhang 2010) — the paper's flagship
//! non-convex penalty (Proposition 7 establishes its α-semi-convexity
//! for γ > 1/L_j).
//!
//! ```text
//! MCP_{λ,γ}(x) = λ|x| − x²/(2γ)   if |x| ≤ γλ
//!              = γλ²/2            if |x| > γλ
//! ```
//!
//! Its prox (the "firm threshold") is single-valued exactly when
//! `step < γ`, i.e. `γ L_j > 1` — the α-semi-convex regime. The solver
//! asserts this via [`Penalty::validate_step`].

use super::Penalty;

/// The MCP penalty; its prox is the firm threshold, which — unlike
/// soft-thresholding — leaves large coefficients unshrunk (the paper's
/// unbiasedness story).
///
/// # Examples
///
/// ```
/// use skglm::penalty::{Mcp, Penalty};
///
/// let pen = Mcp::new(1.0, 3.0); // λ = 1, γ = 3
/// // small inputs are thresholded to zero like the Lasso…
/// assert_eq!(pen.prox(0.8, 1.0, 0), 0.0);
/// // …but inputs beyond γλ pass through unshrunk (no bias)
/// assert_eq!(pen.prox(5.0, 1.0, 0), 5.0);
/// // the penalty saturates at γλ²/2
/// assert_eq!(pen.value(100.0, 0), 1.5);
/// assert!(!pen.is_convex());
/// ```
#[derive(Clone, Debug)]
pub struct Mcp {
    pub lambda: f64,
    pub gamma: f64,
}

impl Mcp {
    pub fn new(lambda: f64, gamma: f64) -> Self {
        assert!(lambda >= 0.0);
        assert!(gamma > 0.0, "MCP gamma must be positive");
        Self { lambda, gamma }
    }
}

impl Penalty for Mcp {
    #[inline]
    fn value(&self, beta_j: f64, _j: usize) -> f64 {
        let a = beta_j.abs();
        if a <= self.gamma * self.lambda {
            self.lambda * a - beta_j * beta_j / (2.0 * self.gamma)
        } else {
            0.5 * self.gamma * self.lambda * self.lambda
        }
    }

    /// Firm thresholding; requires `step < γ` (α-semi-convex regime).
    #[inline]
    fn prox(&self, v: f64, step: f64, _j: usize) -> f64 {
        debug_assert!(
            step < self.gamma,
            "MCP prox outside semi-convex regime: step={step} >= gamma={}",
            self.gamma
        );
        let a = v.abs();
        let tau = step * self.lambda;
        if a <= tau {
            0.0
        } else if a <= self.gamma * self.lambda {
            v.signum() * (a - tau) / (1.0 - step / self.gamma)
        } else {
            v
        }
    }

    #[inline]
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, _j: usize) -> f64 {
        let a = beta_j.abs();
        if beta_j == 0.0 {
            // ∂MCP(0) = [−λ, λ] (Eq. 2 of the paper)
            (grad_j.abs() - self.lambda).max(0.0)
        } else if a < self.gamma * self.lambda {
            // MCP'(β) = λ sign(β) − β/γ
            (grad_j + self.lambda * beta_j.signum() - beta_j / self.gamma).abs()
        } else {
            // flat region: MCP' = 0
            grad_j.abs()
        }
    }

    #[inline]
    fn in_gsupp(&self, beta_j: f64) -> bool {
        beta_j != 0.0
    }

    fn is_convex(&self) -> bool {
        false
    }

    fn validate_step(&self, step: f64) {
        assert!(
            step < self.gamma,
            "MCP with gamma={} is not alpha-semi-convex for step {step} (need gamma*L_j > 1); \
             normalise columns or increase gamma",
            self.gamma
        );
    }

    fn name(&self) -> &'static str {
        "mcp"
    }

    fn as_batchable(&self) -> Option<super::BatchPenalty> {
        Some(super::BatchPenalty::Mcp(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::penalty::test_helpers::assert_prox_is_minimizer;

    #[test]
    fn value_matches_definition() {
        let p = Mcp::new(1.0, 3.0);
        assert_eq!(p.value(0.0, 0), 0.0);
        assert!((p.value(1.0, 0) - (1.0 - 1.0 / 6.0)).abs() < 1e-15);
        // beyond gamma*lambda = 3: constant
        assert!((p.value(5.0, 0) - 1.5).abs() < 1e-15);
        assert_eq!(p.value(5.0, 0), p.value(-100.0, 0));
    }

    #[test]
    fn value_is_continuous_at_knee() {
        let p = Mcp::new(0.8, 2.5);
        let knee = 0.8 * 2.5;
        assert!((p.value(knee - 1e-9, 0) - p.value(knee + 1e-9, 0)).abs() < 1e-8);
    }

    #[test]
    fn prox_regions() {
        let p = Mcp::new(1.0, 3.0);
        let step = 1.0;
        // dead zone
        assert_eq!(p.prox(0.5, step, 0), 0.0);
        // firm region: (|v|-1)/(1-1/3) = 1.5(|v|-1)
        assert!((p.prox(2.0, step, 0) - 1.5).abs() < 1e-15);
        assert!((p.prox(-2.0, step, 0) + 1.5).abs() < 1e-15);
        // identity region
        assert_eq!(p.prox(4.0, step, 0), 4.0);
    }

    #[test]
    fn prox_is_continuous_at_region_boundaries() {
        let p = Mcp::new(1.0, 3.0);
        let step = 0.8;
        for &v in &[step * 1.0, 3.0] {
            let lo = p.prox(v - 1e-9, step, 0);
            let hi = p.prox(v + 1e-9, step, 0);
            assert!((lo - hi).abs() < 1e-6, "jump at {v}: {lo} vs {hi}");
        }
    }

    #[test]
    fn prox_minimizes_objective_in_semiconvex_regime() {
        let p = Mcp::new(0.9, 2.0);
        for &v in &[-4.0, -1.5, -0.4, 0.0, 0.6, 1.9, 5.0] {
            for &step in &[0.3, 1.0, 1.9] {
                assert_prox_is_minimizer(&p, v, step, 1e-5);
            }
        }
    }

    #[test]
    fn subdiff_distance_flags_unbiasedness() {
        // Large coefficients: MCP' = 0 so stationarity only needs grad = 0
        // (no shrinkage bias — the paper's Figure 1 story).
        let p = Mcp::new(1.0, 3.0);
        assert_eq!(p.subdiff_distance(10.0, 0.0, 0), 0.0);
        assert!((p.subdiff_distance(10.0, 0.3, 0) - 0.3).abs() < 1e-15);
        // small coefficient: needs grad = -(λ sign − β/γ)
        let beta = 1.5;
        let grad = -(1.0 - beta / 3.0);
        assert!(p.subdiff_distance(beta, grad, 0) < 1e-15);
    }

    #[test]
    #[should_panic(expected = "not alpha-semi-convex")]
    fn validate_step_rejects_bad_regime() {
        Mcp::new(1.0, 0.5).validate_step(1.0);
    }
}
