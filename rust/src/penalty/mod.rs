//! Separable penalties `g(β) = Σ_j g_j(β_j)` of Problem (1) — convex and
//! non-convex.
//!
//! A penalty exposes exactly the information the paper's algorithm needs:
//! its value, its proximal operator (Assumption: computable exactly), the
//! distance from `−∇_j f` to the Fréchet subdifferential `∂g_j(β_j)`
//! (the working-set score of Eq. 2), and generalized-support membership
//! (Definition 4). Penalties for which `score^∂` is uninformative (ℓ_q,
//! q<1 — Appendix C, Example 1) opt into the fixed-point-violation score
//! `score^cd` (Eq. 24) via [`Penalty::use_cd_score`].

pub mod batch;
pub mod block;
pub mod box_ind;
pub mod l1;
pub mod l1_l2;
pub mod lq;
pub mod mcp;
pub mod scad;
pub mod weighted_l1;

pub use batch::BatchPenalty;
pub use block::{
    BlockL21, BlockMcp, BlockPenalty, BlockScad, GroupLasso, GroupMcp, GroupScad,
    WeightedGroupLasso,
};
pub use box_ind::BoxIndicator;
pub use l1::L1;
pub use l1_l2::L1L2;
pub use lq::Lq;
pub use mcp::Mcp;
pub use scad::Scad;
pub use weighted_l1::WeightedL1;

/// A separable penalty term.
pub trait Penalty: Clone + Send + Sync {
    /// `g_j(β_j)`. Must be lower-bounded (Assumption 2); indicator
    /// penalties return 0 inside and `f64::INFINITY` outside.
    fn value(&self, beta_j: f64, j: usize) -> f64;

    /// `prox_{step · g_j}(v) = argmin_x ½(x − v)² + step·g_j(x)`.
    ///
    /// The CD update (Algorithm 3) calls this with `step = 1/L_j`. For the
    /// non-convex penalties the closed forms are valid in their
    /// α-semi-convex regime (MCP: γ > step; SCAD: γ > 1 + step), which the
    /// constructors and [`Penalty::validate_step`] enforce.
    fn prox(&self, v: f64, step: f64, j: usize) -> f64;

    /// `dist(−grad_j, ∂g_j(β_j))` — the score of Eq. (2). `grad_j` is
    /// `∇_j f(β)`.
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, j: usize) -> f64;

    /// Is `∂g_j` a singleton at `beta_j` (generalized support,
    /// Definition 4)?
    fn in_gsupp(&self, beta_j: f64) -> bool;

    /// Whether this penalty is convex (screening/duality shortcuts apply).
    fn is_convex(&self) -> bool;

    /// Appendix-C penalties (ℓ_q) return true: the solver scores features
    /// by the fixed-point violation `|β_j − prox_{g_j/L_j}(β_j − ∇_j f/L_j)|`
    /// instead of the subdifferential distance.
    fn use_cd_score(&self) -> bool {
        false
    }

    /// Panic if `step = 1/L_j` lies outside the penalty's validity regime
    /// (constructors can't check this without the datafit).
    fn validate_step(&self, _step: f64) {}

    fn name(&self) -> &'static str;

    /// `Σ_j g_j(β_j)`.
    fn value_sum(&self, beta: &[f64]) -> f64 {
        beta.iter().enumerate().map(|(j, &b)| self.value(b, j)).sum()
    }

    /// Batched-solver opt-in: penalties the many-fit engine can carry as
    /// a [`BatchPenalty`] member return their enum form (the scheduler's
    /// fusion layer fuses only jobs whose penalties are batchable).
    /// Position-dependent or block penalties stay `None`.
    fn as_batchable(&self) -> Option<BatchPenalty> {
        None
    }
}

/// Soft-thresholding `ST(v, t) = sign(v)·max(|v| − t, 0)` — shared by
/// several prox implementations.
#[inline]
pub fn soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

#[cfg(test)]
pub(crate) mod test_helpers {
    use super::Penalty;

    /// Brute-force check that `prox(v, step)` minimises
    /// `½(x−v)² + step·g(x)` against a dense grid of candidates —
    /// the ground-truth oracle every penalty's prox test uses.
    pub fn assert_prox_is_minimizer<P: Penalty>(pen: &P, v: f64, step: f64, tol: f64) {
        let x_star = pen.prox(v, step, 0);
        let obj = |x: f64| 0.5 * (x - v) * (x - v) + step * pen.value(x, 0);
        let o_star = obj(x_star);
        assert!(
            o_star.is_finite(),
            "{}: prox({v}, {step}) = {x_star} has non-finite objective",
            pen.name()
        );
        let lim = 2.0 * v.abs() + 2.0;
        let mut x = -lim;
        while x <= lim {
            let o = obj(x);
            assert!(
                o_star <= o + tol,
                "{}: prox({v},{step})={x_star} (obj {o_star}) beaten by x={x} (obj {o})",
                pen.name()
            );
            x += lim / 2000.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }
}
