//! Closed penalty universe for the batched many-fit engine (FaSTGLZ).
//!
//! Batched members live in one `Vec`, so their penalties must share a
//! concrete type; a two-arm enum keeps the CD hot loop monomorphic and
//! inlinable (same argument as [`crate::linalg::Design`]). Only the
//! separable scalar penalties with a per-λ closed form that the batch
//! scheduler fuses today are included — a penalty opts in by overriding
//! [`Penalty::as_batchable`].

use super::{L1, Mcp, Penalty};

/// A batchable separable penalty: the member-fit penalty type of the
/// batched solver and the scheduler's fusion layer. `with_lambda`
/// re-anchors the regularisation level while preserving every other
/// hyper-parameter — the λ-grid continuation hook.
#[derive(Clone, Debug)]
pub enum BatchPenalty {
    L1(L1),
    Mcp(Mcp),
}

impl BatchPenalty {
    /// Same penalty family/shape at a different λ (warm-start
    /// continuation along a shared ratio grid).
    pub fn with_lambda(&self, lambda: f64) -> BatchPenalty {
        match self {
            BatchPenalty::L1(_) => BatchPenalty::L1(L1::new(lambda)),
            BatchPenalty::Mcp(p) => BatchPenalty::Mcp(Mcp::new(lambda, p.gamma)),
        }
    }

    /// Current regularisation level.
    pub fn lambda(&self) -> f64 {
        match self {
            BatchPenalty::L1(p) => p.lambda,
            BatchPenalty::Mcp(p) => p.lambda,
        }
    }
}

impl Penalty for BatchPenalty {
    #[inline]
    fn value(&self, beta_j: f64, j: usize) -> f64 {
        match self {
            BatchPenalty::L1(p) => p.value(beta_j, j),
            BatchPenalty::Mcp(p) => p.value(beta_j, j),
        }
    }

    #[inline]
    fn prox(&self, v: f64, step: f64, j: usize) -> f64 {
        match self {
            BatchPenalty::L1(p) => p.prox(v, step, j),
            BatchPenalty::Mcp(p) => p.prox(v, step, j),
        }
    }

    #[inline]
    fn subdiff_distance(&self, beta_j: f64, grad_j: f64, j: usize) -> f64 {
        match self {
            BatchPenalty::L1(p) => p.subdiff_distance(beta_j, grad_j, j),
            BatchPenalty::Mcp(p) => p.subdiff_distance(beta_j, grad_j, j),
        }
    }

    #[inline]
    fn in_gsupp(&self, beta_j: f64) -> bool {
        match self {
            BatchPenalty::L1(p) => p.in_gsupp(beta_j),
            BatchPenalty::Mcp(p) => p.in_gsupp(beta_j),
        }
    }

    fn is_convex(&self) -> bool {
        match self {
            BatchPenalty::L1(p) => p.is_convex(),
            BatchPenalty::Mcp(p) => p.is_convex(),
        }
    }

    fn use_cd_score(&self) -> bool {
        match self {
            BatchPenalty::L1(p) => p.use_cd_score(),
            BatchPenalty::Mcp(p) => p.use_cd_score(),
        }
    }

    fn validate_step(&self, step: f64) {
        match self {
            BatchPenalty::L1(p) => p.validate_step(step),
            BatchPenalty::Mcp(p) => p.validate_step(step),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            BatchPenalty::L1(p) => p.name(),
            BatchPenalty::Mcp(p) => p.name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delegates_bitwise_to_wrapped_penalty() {
        let l1 = L1::new(0.7);
        let mcp = Mcp::new(0.7, 3.0);
        let bl1 = BatchPenalty::L1(l1.clone());
        let bmcp = BatchPenalty::Mcp(mcp.clone());
        for &v in &[-2.0, -0.3, 0.0, 0.5, 4.0] {
            assert_eq!(bl1.prox(v, 1.0, 0).to_bits(), l1.prox(v, 1.0, 0).to_bits());
            assert_eq!(
                bmcp.prox(v, 1.0, 0).to_bits(),
                mcp.prox(v, 1.0, 0).to_bits()
            );
            assert_eq!(bl1.value(v, 0).to_bits(), l1.value(v, 0).to_bits());
            assert_eq!(
                bmcp.subdiff_distance(v, 0.3, 0).to_bits(),
                mcp.subdiff_distance(v, 0.3, 0).to_bits()
            );
        }
        assert_eq!(bl1.name(), "l1");
        assert_eq!(bmcp.name(), "mcp");
        assert!(bl1.is_convex());
        assert!(!bmcp.is_convex());
    }

    #[test]
    fn with_lambda_preserves_shape() {
        let b = BatchPenalty::Mcp(Mcp::new(1.0, 3.0));
        let b2 = b.with_lambda(0.25);
        assert_eq!(b2.lambda(), 0.25);
        match b2 {
            BatchPenalty::Mcp(p) => assert_eq!(p.gamma, 3.0),
            _ => panic!("family changed"),
        }
        assert_eq!(b.with_lambda(0.5).lambda(), 0.5);
    }

    #[test]
    fn as_batchable_roundtrip() {
        assert!(L1::new(1.0).as_batchable().is_some());
        assert!(Mcp::new(1.0, 3.0).as_batchable().is_some());
        assert!(crate::penalty::Scad::new(1.0, 3.7).as_batchable().is_none());
    }
}
