//! Bench scenario `glms`: the prox-Newton GLM subsystem measured against
//! the OWL-QN (orthant-wise L-BFGS) baseline on ℓ1-Poisson and ℓ1-probit
//! problems across n/p/density grids.
//!
//! Per workload × λ the runner records, for each solver, the wall time to
//! its own stopping criterion, the final objective, and the relative
//! objective gap to the best of the two — the acceptance bar is
//! `rel_gap ≤ 1e-6` on every grid point (both solvers target the same
//! convex optimum). Results land in `results/glms/` and — the
//! perf-trajectory anchor — `BENCH_glms.json` at the repo root (skipped
//! when `SKGLM_RESULTS` redirects outputs, e.g. under `cargo test`).

use crate::bench::figures::Scale;
use crate::bench::kernel_bench::time_it;
use crate::bench::report::{ensure_dir, results_dir, write_markdown};
use crate::data::{
    poisson_correlated, probit_correlated, sparse, with_poisson_targets, with_probit_targets,
    CorrelatedSpec, Dataset, SparseSpec,
};
use crate::datafit::{Datafit, Poisson, Probit};
use crate::penalty::L1;
use crate::solver::baselines::owlqn::solve_owlqn;
use crate::solver::{glm_lambda_max, solve_prox_newton, SolverOpts};
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;
use std::path::PathBuf;

/// One solved (workload, λ, solver) grid point.
#[derive(Clone, Debug)]
pub struct GlmBenchRow {
    /// `poisson` | `probit`
    pub model: String,
    /// workload shape, e.g. `200x400`
    pub shape: String,
    /// λ / λ_max
    pub lambda_ratio: f64,
    /// `prox_newton` | `owlqn`
    pub solver: String,
    /// median wall time (ms)
    pub millis: f64,
    pub objective: f64,
    /// (objective − best objective across solvers) / |best|
    pub rel_gap: f64,
    pub support_size: usize,
    /// outer iterations (prox-Newton) or L-BFGS iterations
    pub iters: usize,
}

fn run_model<D: Datafit + Default>(
    model: &str,
    shape: &str,
    ds: &Dataset,
    lam_ratios: &[f64],
    warmup: usize,
    reps: usize,
    rows: &mut Vec<GlmBenchRow>,
) {
    let shape = shape.to_string();
    let lam_max = glm_lambda_max(&D::default(), &ds.design, &ds.y);
    for &ratio in lam_ratios {
        let lam = lam_max * ratio;
        let opts = SolverOpts::default().with_tol(1e-9);

        let mut pn_res = None;
        let pn_secs = time_it(warmup, reps, || {
            let mut f = D::default();
            pn_res =
                Some(solve_prox_newton(&ds.design, &ds.y, &mut f, &L1::new(lam), &opts, None));
        });
        let pn = pn_res.expect("timed at least once");

        let mut owl_res = None;
        let owl_secs = time_it(warmup, reps, || {
            let mut f = D::default();
            owl_res = Some(solve_owlqn(&ds.design, &ds.y, &mut f, lam, 10, 5000, 1e-9));
        });
        let owl = owl_res.expect("timed at least once");

        let best = pn.objective.min(owl.objective);
        let denom = best.abs().max(1e-12);
        rows.push(GlmBenchRow {
            model: model.to_string(),
            shape: shape.clone(),
            lambda_ratio: ratio,
            solver: "prox_newton".to_string(),
            millis: pn_secs * 1e3,
            objective: pn.objective,
            rel_gap: (pn.objective - best) / denom,
            support_size: pn.support().len(),
            iters: pn.n_outer,
        });
        rows.push(GlmBenchRow {
            model: model.to_string(),
            shape: shape.clone(),
            lambda_ratio: ratio,
            solver: "owlqn".to_string(),
            millis: owl_secs * 1e3,
            objective: owl.objective,
            rel_gap: (owl.objective - best) / denom,
            support_size: owl.beta.iter().filter(|&&b| b != 0.0).count(),
            iters: owl.iters,
        });
    }
}

/// Run the GLM grid and persist `BENCH_glms.json`.
pub fn run_glms(scale: Scale) -> Result<Vec<PathBuf>> {
    // dense n×p grid + sparse (n, p, density) grid + λ-ratio grid
    #[allow(clippy::type_complexity)]
    let (dense_shapes, sparse_shapes, lam_ratios, warmup, reps): (
        Vec<(usize, usize)>,
        Vec<(usize, usize, f64)>,
        Vec<f64>,
        usize,
        usize,
    ) = match scale {
        Scale::Smoke => (vec![(100, 200)], vec![(300, 1000, 5e-3)], vec![0.1], 1, 3),
        Scale::Full => (
            vec![(200, 400), (500, 2000), (1000, 4000)],
            vec![(2000, 20_000, 1e-3), (2000, 20_000, 1e-2)],
            vec![0.1, 0.02],
            2,
            5,
        ),
    };

    let mut rows: Vec<GlmBenchRow> = Vec::new();
    for &(n, p) in &dense_shapes {
        let spec = CorrelatedSpec { n, p, rho: 0.4, nnz: (p / 40).max(2), snr: 0.0 };
        let shape = format!("{n}x{p}");
        let pois = poisson_correlated(spec, 42);
        run_model::<Poisson>("poisson", &shape, &pois, &lam_ratios, warmup, reps, &mut rows);
        let prob = probit_correlated(spec, 42);
        run_model::<Probit>("probit", &shape, &prob, &lam_ratios, warmup, reps, &mut rows);
    }
    for &(n, p, density) in &sparse_shapes {
        let spec = SparseSpec { n, p, density, support_frac: 0.005, snr: 5.0, binary: false };
        let shape = format!("{n}x{p}@{density:e}");
        let base = sparse("glms", spec, 7);
        let pois = with_poisson_targets(base.clone(), 7);
        run_model::<Poisson>("poisson", &shape, &pois, &lam_ratios, warmup, reps, &mut rows);
        let prob = with_probit_targets(base, 7);
        run_model::<Probit>("probit", &shape, &prob, &lam_ratios, warmup, reps, &mut rows);
    }

    // ---- report ----
    let mut t = Table::new(&[
        "model", "shape", "lambda_ratio", "solver", "median_ms", "objective", "rel_gap",
        "support", "iters",
    ]);
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            r.shape.clone(),
            format!("{:.3}", r.lambda_ratio),
            r.solver.clone(),
            format!("{:.2}", r.millis),
            format!("{:.9e}", r.objective),
            format!("{:.2e}", r.rel_gap),
            r.support_size.to_string(),
            r.iters.to_string(),
        ]);
    }
    let md = write_markdown("glms", "prox_newton_vs_owlqn", &t)?;

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .with("model", r.model.as_str())
                .with("shape", r.shape.as_str())
                .with("lambda_ratio", r.lambda_ratio)
                .with("solver", r.solver.as_str())
                .with("median_ms", r.millis)
                .with("objective", r.objective)
                .with("rel_gap", r.rel_gap)
                .with("support", r.support_size)
                .with("iters", r.iters)
        })
        .collect();
    let json = Json::obj()
        .with("bench", "glms")
        .with(
            "scale",
            match scale {
                Scale::Smoke => "smoke",
                Scale::Full => "full",
            },
        )
        .with("agreement_bar", 1e-6)
        .with("rows", Json::Arr(jrows));

    let dir = results_dir().join("glms");
    ensure_dir(&dir)?;
    let json_path = dir.join("BENCH_glms.json");
    std::fs::write(&json_path, json.render())?;
    let mut outputs = vec![json_path, md];
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_glms.json");
        std::fs::write(&root, json.render())?;
        outputs.push(root);
    }

    // headline: worst cross-solver objective gap + speedup
    let worst_gap = rows.iter().map(|r| r.rel_gap).fold(0.0f64, f64::max);
    eprintln!("[glms] worst cross-solver relative objective gap: {worst_gap:.2e} (bar 1e-6)");
    for model in ["poisson", "probit"] {
        let (mut pn_ms, mut owl_ms) = (0.0, 0.0);
        for r in rows.iter().filter(|r| r.model == model) {
            match r.solver.as_str() {
                "prox_newton" => pn_ms += r.millis,
                _ => owl_ms += r.millis,
            }
        }
        if pn_ms > 0.0 {
            eprintln!(
                "[glms] {model}: prox-Newton {pn_ms:.1}ms total vs OWL-QN {owl_ms:.1}ms ({:.2}x)",
                owl_ms / pn_ms
            );
        }
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_runs_meets_agreement_bar_and_persists_json() {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_glms_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let out = run_glms(Scale::Smoke).unwrap();
        assert!(!out.is_empty());
        for p in &out {
            assert!(p.exists(), "{}", p.display());
        }
        let raw = std::fs::read_to_string(&out[0]).unwrap();
        assert!(raw.contains("\"bench\":\"glms\""));
        assert!(raw.contains("poisson"));
        assert!(raw.contains("probit"));
        assert!(raw.contains("prox_newton"));
        assert!(raw.contains("owlqn"));
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
