//! Table 1 reproduction: the package-capability matrix. The competitor
//! rows restate the paper's published table (they describe *other*
//! software); the skglm-rs row is self-measured by probing the library:
//! acceleration = Anderson is wired into the inner solver, huge-scale =
//! sparse designs stream through CSC, non-convex = MCP/SCAD/ℓ_q penalties
//! exist, modular = a new model is one `Datafit` + one `Penalty` impl.

use crate::util::table::Table;

pub struct CapabilityRow {
    pub name: &'static str,
    pub acceleration: bool,
    pub huge_scale: bool,
    pub non_convex: bool,
    pub modular: bool,
    pub language: &'static str,
}

/// The paper's Table 1 rows (as published), plus ours.
pub fn capability_rows() -> Vec<CapabilityRow> {
    vec![
        CapabilityRow { name: "glmnet", acceleration: false, huge_scale: false, non_convex: false, modular: false, language: "Fortran" },
        CapabilityRow { name: "scikit-learn", acceleration: false, huge_scale: false, non_convex: false, modular: false, language: "Cython" },
        CapabilityRow { name: "lightning", acceleration: false, huge_scale: false, non_convex: false, modular: true, language: "Cython" },
        CapabilityRow { name: "celer", acceleration: true, huge_scale: true, non_convex: false, modular: false, language: "Cython" },
        CapabilityRow { name: "picasso", acceleration: false, huge_scale: false, non_convex: true, modular: false, language: "C++" },
        CapabilityRow { name: "pyGLMnet", acceleration: false, huge_scale: false, non_convex: false, modular: true, language: "Python" },
        CapabilityRow { name: "fireworks", acceleration: false, huge_scale: true, non_convex: true, modular: false, language: "Python" },
        CapabilityRow {
            name: "skglm-rs (ours)",
            acceleration: self_check_acceleration(),
            huge_scale: self_check_huge_scale(),
            non_convex: self_check_non_convex(),
            modular: true, // Datafit + Penalty traits; see datafit/, penalty/
            language: "Rust + JAX/Pallas",
        },
    ]
}

/// Anderson acceleration measurably reduces epochs on a small Lasso.
fn self_check_acceleration() -> bool {
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::L1;
    use crate::solver::{solve, SolverOpts};
    let ds = correlated(CorrelatedSpec { n: 60, p: 80, rho: 0.6, nnz: 6, snr: 10.0 }, 0);
    let lam = crate::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 50.0;
    let run = |m: usize| {
        let mut f = Quadratic::new();
        let mut opts = SolverOpts::default().with_tol(1e-10).without_ws();
        opts.anderson_m = m;
        solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &opts, None, None).n_epochs
    };
    run(5) <= run(0)
}

/// Sparse designs solve through the same code path.
fn self_check_huge_scale() -> bool {
    use crate::data::paper_dataset_small;
    use crate::datafit::Quadratic;
    use crate::penalty::L1;
    use crate::solver::{solve, SolverOpts};
    let ds = match paper_dataset_small("news20", 0) {
        Some(d) => d,
        None => return false,
    };
    let lam = crate::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
    let mut f = Quadratic::new();
    solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default().with_tol(1e-6), None, None)
        .converged
}

/// Non-convex penalties converge to critical points.
fn self_check_non_convex() -> bool {
    use crate::data::{correlated, CorrelatedSpec};
    use crate::estimators::McpRegressor;
    let ds = correlated(CorrelatedSpec { n: 80, p: 100, rho: 0.4, nnz: 8, snr: 10.0 }, 1);
    let lam = crate::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
    McpRegressor::new(lam, 3.0).with_tol(1e-7).fit(&ds.design, &ds.y).0.converged
}

/// Render Table 1.
pub fn capability_table() -> Table {
    let mark = |b: bool| if b { "✓" } else { "✗" }.to_string();
    let mut t = Table::new(&["package", "accel", "huge-scale", "non-convex", "modular", "language"]);
    for r in capability_rows() {
        t.row(vec![
            r.name.to_string(),
            mark(r.acceleration),
            mark(r.huge_scale),
            mark(r.non_convex),
            mark(r.modular),
            r.language.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn our_row_self_checks_all_capabilities() {
        let rows = capability_rows();
        let ours = rows.last().unwrap();
        assert_eq!(ours.name, "skglm-rs (ours)");
        assert!(ours.acceleration, "Anderson must help on the probe problem");
        assert!(ours.huge_scale, "sparse solve must converge");
        assert!(ours.non_convex, "MCP must converge");
        assert!(ours.modular);
    }

    #[test]
    fn table_has_all_packages() {
        let t = capability_table();
        assert_eq!(t.n_rows(), 8);
        let md = t.markdown();
        for name in ["glmnet", "celer", "picasso", "fireworks", "skglm-rs"] {
            assert!(md.contains(name), "{md}");
        }
    }
}
