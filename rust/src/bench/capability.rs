//! Table 1 reproduction: the package-capability matrix. The competitor
//! rows restate the paper's published table (they describe *other*
//! software); the skglm-rs row is derived from [`probe_library`] — four
//! **live** probes against the compiled library, not hardcoded claims:
//! acceleration = Anderson measurably helps the inner solver, huge-scale
//! = sparse designs stream through CSC, non-convex = MCP converges to a
//! critical point, modular = a `Penalty` impl written *outside* the
//! library solves through the generic solver unmodified.

use crate::util::table::Table;

pub struct CapabilityRow {
    pub name: &'static str,
    pub acceleration: bool,
    pub huge_scale: bool,
    pub non_convex: bool,
    pub modular: bool,
    pub language: &'static str,
}

/// What the library can actually do right now, each flag backed by a
/// probe that exercises the corresponding code path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SelfProbes {
    pub acceleration: bool,
    pub huge_scale: bool,
    pub non_convex: bool,
    pub modular: bool,
}

/// Run every capability probe against the live library.
pub fn probe_library() -> SelfProbes {
    SelfProbes {
        acceleration: self_check_acceleration(),
        huge_scale: self_check_huge_scale(),
        non_convex: self_check_non_convex(),
        modular: self_check_modular(),
    }
}

/// The paper's Table 1 rows (as published), plus ours.
pub fn capability_rows() -> Vec<CapabilityRow> {
    let probes = probe_library();
    vec![
        CapabilityRow { name: "glmnet", acceleration: false, huge_scale: false, non_convex: false, modular: false, language: "Fortran" },
        CapabilityRow { name: "scikit-learn", acceleration: false, huge_scale: false, non_convex: false, modular: false, language: "Cython" },
        CapabilityRow { name: "lightning", acceleration: false, huge_scale: false, non_convex: false, modular: true, language: "Cython" },
        CapabilityRow { name: "celer", acceleration: true, huge_scale: true, non_convex: false, modular: false, language: "Cython" },
        CapabilityRow { name: "picasso", acceleration: false, huge_scale: false, non_convex: true, modular: false, language: "C++" },
        CapabilityRow { name: "pyGLMnet", acceleration: false, huge_scale: false, non_convex: false, modular: true, language: "Python" },
        CapabilityRow { name: "fireworks", acceleration: false, huge_scale: true, non_convex: true, modular: false, language: "Python" },
        CapabilityRow {
            name: "skglm-rs (ours)",
            acceleration: probes.acceleration,
            huge_scale: probes.huge_scale,
            non_convex: probes.non_convex,
            modular: probes.modular,
            language: "Rust + JAX/Pallas",
        },
    ]
}

/// Anderson acceleration measurably reduces epochs on a small Lasso.
fn self_check_acceleration() -> bool {
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::L1;
    use crate::solver::{solve, SolverOpts};
    let ds = correlated(CorrelatedSpec { n: 60, p: 80, rho: 0.6, nnz: 6, snr: 10.0 }, 0);
    let lam = crate::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 50.0;
    let run = |m: usize| {
        let mut f = Quadratic::new();
        let mut opts = SolverOpts::default().with_tol(1e-10).without_ws();
        opts.anderson_m = m;
        solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &opts, None, None).n_epochs
    };
    run(5) <= run(0)
}

/// Sparse designs solve through the same code path.
fn self_check_huge_scale() -> bool {
    use crate::data::paper_dataset_small;
    use crate::datafit::Quadratic;
    use crate::penalty::L1;
    use crate::solver::{solve, SolverOpts};
    let ds = match paper_dataset_small("news20", 0) {
        Some(d) => d,
        None => return false,
    };
    let lam = crate::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
    let mut f = Quadratic::new();
    solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &SolverOpts::default().with_tol(1e-6), None, None)
        .converged
}

/// Non-convex penalties converge to critical points.
fn self_check_non_convex() -> bool {
    use crate::data::{correlated, CorrelatedSpec};
    use crate::estimators::McpRegressor;
    let ds = correlated(CorrelatedSpec { n: 80, p: 100, rho: 0.4, nnz: 8, snr: 10.0 }, 1);
    let lam = crate::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
    McpRegressor::new(lam, 3.0).with_tol(1e-7).fit(&ds.design, &ds.y).0.converged
}

/// Modularity is a *user*-facing claim: a penalty the library has never
/// seen — defined right here, the way a downstream crate would — must
/// solve through the generic solver with no solver changes. The probe
/// penalty is a feature-scaled ℓ1 (`g_j(x) = λ·(1 + j mod 2)·|x|`, exact
/// prox via soft-thresholding) that is deliberately NOT one of the
/// shipped `penalty::*` types.
fn self_check_modular() -> bool {
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::Quadratic;
    use crate::penalty::{soft_threshold, Penalty};
    use crate::solver::{solve, SolverOpts};

    #[derive(Clone)]
    struct ProbeScaledL1 {
        lam: f64,
    }
    impl ProbeScaledL1 {
        fn scale(&self, j: usize) -> f64 {
            self.lam * (1 + j % 2) as f64
        }
    }
    impl Penalty for ProbeScaledL1 {
        fn value(&self, beta_j: f64, j: usize) -> f64 {
            self.scale(j) * beta_j.abs()
        }
        fn prox(&self, v: f64, step: f64, j: usize) -> f64 {
            soft_threshold(v, step * self.scale(j))
        }
        fn subdiff_distance(&self, beta_j: f64, grad_j: f64, j: usize) -> f64 {
            let s = self.scale(j);
            if beta_j == 0.0 {
                ((-grad_j).abs() - s).max(0.0)
            } else {
                (-grad_j - beta_j.signum() * s).abs()
            }
        }
        fn in_gsupp(&self, beta_j: f64) -> bool {
            beta_j != 0.0
        }
        fn is_convex(&self) -> bool {
            true
        }
        fn name(&self) -> &'static str {
            "probe_scaled_l1"
        }
    }

    let ds = correlated(CorrelatedSpec { n: 60, p: 80, rho: 0.5, nnz: 6, snr: 10.0 }, 2);
    let lam = crate::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 20.0;
    let mut f = Quadratic::new();
    let tol = 1e-8;
    let res = solve(
        &ds.design,
        &ds.y,
        &mut f,
        &ProbeScaledL1 { lam },
        &SolverOpts::default().with_tol(tol),
        None,
        None,
    );
    res.converged && res.kkt <= tol && res.objective.is_finite()
}

/// Render Table 1.
pub fn capability_table() -> Table {
    let mark = |b: bool| if b { "✓" } else { "✗" }.to_string();
    let mut t = Table::new(&["package", "accel", "huge-scale", "non-convex", "modular", "language"]);
    for r in capability_rows() {
        t.row(vec![
            r.name.to_string(),
            mark(r.acceleration),
            mark(r.huge_scale),
            mark(r.non_convex),
            mark(r.modular),
            r.language.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn our_row_self_checks_all_capabilities() {
        let probes = probe_library();
        assert!(probes.acceleration, "Anderson must help on the probe problem");
        assert!(probes.huge_scale, "sparse solve must converge");
        assert!(probes.non_convex, "MCP must converge");
        assert!(
            probes.modular,
            "an externally-defined Penalty must solve through the generic solver"
        );
    }

    #[test]
    fn our_row_is_the_live_probes_not_hardcoded_trues() {
        let probes = probe_library();
        let rows = capability_rows();
        let ours = rows.last().unwrap();
        assert_eq!(ours.name, "skglm-rs (ours)");
        assert_eq!(
            (ours.acceleration, ours.huge_scale, ours.non_convex, ours.modular),
            (probes.acceleration, probes.huge_scale, probes.non_convex, probes.modular),
            "the table row must restate probe_library() verbatim"
        );
    }

    #[test]
    fn table_has_all_packages() {
        let t = capability_table();
        assert_eq!(t.n_rows(), 8);
        let md = t.markdown();
        for name in ["glmnet", "celer", "picasso", "fireworks", "skglm-rs"] {
            assert!(md.contains(name), "{md}");
        }
    }
}
