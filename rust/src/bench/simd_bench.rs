//! Bench scenario `simd`: the ISA × precision × shape × B micro-kernel
//! grid behind ISSUE 10's kernel floor.
//!
//! Three workload families, each timed per available ISA:
//! - `xtr_panel`    — dense panel `Xᵀr` (`simd::matvec_t_panel_with`),
//!   the solver's full-design scoring pass;
//! - `xtr_multirhs` — the B-RHS panel `Xᵀ R`
//!   (`simd::matmul_t_panel_with`), the batched-fit scoring pass;
//! - `gram_pairs`   — Gram-assembly pair dots: the f64 rows run the
//!   gathered-dots kernel, the `f32`/`mixed` rows run the shadow-design
//!   [`simd::reduced_dot`] path the Gram store uses under reduced
//!   precision.
//!
//! Speedups are quoted against the scalar-f64 variant of the same
//! (kernel, shape, B) cell. The headline acceptance metrics land in the
//! JSON as `vector_xtr_speedup` (vector panel `Xᵀr` vs scalar at the
//! largest dense shape) and `mixed_gram_speedup` (mixed pair dots vs f64
//! gathered dots); both are `null` — and their `ok` flags vacuously true
//! — when no vector ISA is available (or `--isa scalar` pinned the
//! process), so the CI gate stays meaningful on any host.
//!
//! Results land in `results/simd/` and `BENCH_simd.json` at the repo
//! root (skipped when `SKGLM_RESULTS` redirects outputs).

use crate::bench::figures::Scale;
use crate::bench::kernel_bench::time_it;
use crate::bench::report::{ensure_dir, results_dir, write_markdown};
use crate::data::{correlated, CorrelatedSpec};
use crate::linalg::simd::{self, KernelIsa, Precision, ShadowF32};
use crate::linalg::Design;
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;
use std::hint::black_box;
use std::path::PathBuf;

/// One timed cell of the grid.
#[derive(Clone, Debug)]
pub struct SimdBenchRow {
    /// workload family: `xtr_panel` | `xtr_multirhs` | `gram_pairs`
    pub kernel: String,
    /// dense workload shape, e.g. `10000x1000`
    pub shape: String,
    /// ISA the cell ran under (`scalar`, `avx2fma`, ...)
    pub isa: String,
    /// arithmetic mode: `f64` | `f32` | `mixed`
    pub precision: String,
    /// residual panel width B (1 for single-RHS workloads)
    pub n_rhs: usize,
    /// median wall time
    pub micros: f64,
    /// design entries touched per second, in millions
    pub mitems_per_s: f64,
    /// scalar-f64 median time of this cell / this cell's median time
    pub speedup_vs_scalar_f64: f64,
}

/// The ISAs worth timing on this host: scalar always, plus the active
/// vector ISA when the probe (or `--isa`) selected one.
fn isa_grid() -> Vec<KernelIsa> {
    let active = simd::isa();
    let mut grid = vec![KernelIsa::Scalar];
    if active != KernelIsa::Scalar {
        grid.push(active);
    }
    grid
}

/// Time the single- and multi-RHS panel `Xᵀr` under every ISA.
fn bench_xtr(
    shape: &str,
    design: &Design,
    widths: &[usize],
    warmup: usize,
    reps: usize,
    rows: &mut Vec<SimdBenchRow>,
) {
    let m = match design {
        Design::Dense(m) => m,
        Design::Sparse(_) => return,
    };
    let n = m.nrows();
    let p = m.ncols();
    for &b in widths {
        let r: Vec<f64> = (0..n * b).map(|i| (i as f64 * 0.37).cos()).collect();
        let mut out = vec![0.0; p * b];
        let work = (n * p) as f64 * b as f64;
        let mut scalar_secs = f64::NAN;
        for which in isa_grid() {
            let secs = time_it(warmup, reps, || {
                simd::matmul_t_panel_with(which, m, &r, b, 0..p, &mut out);
                black_box(&out);
            });
            if which == KernelIsa::Scalar {
                scalar_secs = secs;
            }
            rows.push(SimdBenchRow {
                kernel: if b == 1 { "xtr_panel" } else { "xtr_multirhs" }.to_string(),
                shape: shape.to_string(),
                isa: which.as_str().to_string(),
                precision: "f64".to_string(),
                n_rhs: b,
                micros: secs * 1e6,
                mitems_per_s: work / secs / 1e6,
                speedup_vs_scalar_f64: scalar_secs / secs,
            });
        }
    }
}

/// Time Gram-assembly pair dots: f64 gathered dots per ISA, then the
/// shadow-design reduced paths (ISA-independent by construction — the
/// reduced kernels have no FMA variants, so one active-ISA row each).
fn bench_gram(
    shape: &str,
    design: &Design,
    warmup: usize,
    reps: usize,
    rows: &mut Vec<SimdBenchRow>,
) {
    let m = match design {
        Design::Dense(m) => m,
        Design::Sparse(_) => return,
    };
    let p = m.ncols();
    let cols: Vec<usize> = (0..p).collect();
    let rj = m.col(p / 2).to_vec();
    let mut out = vec![0.0; p];
    let work = (m.nrows() * p) as f64;
    let mut scalar_secs = f64::NAN;
    for which in isa_grid() {
        let secs = time_it(warmup, reps, || {
            simd::gather_dots_panel_with(which, m, &rj, &cols, &mut out);
            black_box(&out);
        });
        if which == KernelIsa::Scalar {
            scalar_secs = secs;
        }
        rows.push(SimdBenchRow {
            kernel: "gram_pairs".to_string(),
            shape: shape.to_string(),
            isa: which.as_str().to_string(),
            precision: "f64".to_string(),
            n_rhs: 1,
            micros: secs * 1e6,
            mitems_per_s: work / secs / 1e6,
            speedup_vs_scalar_f64: scalar_secs / secs,
        });
    }
    let shadow = ShadowF32::from_dense(m);
    let rj32 = shadow.col(p / 2);
    for prec in [Precision::Mixed, Precision::F32] {
        let secs = time_it(warmup, reps, || {
            for (o, &c) in out.iter_mut().zip(&cols) {
                *o = simd::reduced_dot(prec, shadow.col(c), rj32);
            }
            black_box(&out);
        });
        rows.push(SimdBenchRow {
            kernel: "gram_pairs".to_string(),
            shape: shape.to_string(),
            isa: simd::isa().as_str().to_string(),
            precision: prec.as_str().to_string(),
            n_rhs: 1,
            micros: secs * 1e6,
            mitems_per_s: work / secs / 1e6,
            speedup_vs_scalar_f64: scalar_secs / secs,
        });
    }
}

/// Run the ISA × precision × shape × B grid and persist `BENCH_simd.json`.
pub fn run_simd(scale: Scale) -> Result<Vec<PathBuf>> {
    let (shapes, widths, warmup, reps): (Vec<(usize, usize)>, Vec<usize>, usize, usize) =
        match scale {
            Scale::Smoke => (vec![(400, 300)], vec![1, 4], 2, 5),
            // full: the acceptance shape (10⁴×10³) plus the fig1-scale
            // panel, B up to the scheduler's sibling-fusion width
            Scale::Full => (vec![(1000, 2000), (10_000, 1000)], vec![1, 4, 8], 3, 9),
        };

    let mut rows: Vec<SimdBenchRow> = Vec::new();
    let largest = shapes
        .iter()
        .max_by_key(|&&(n, p)| n * p)
        .map(|&(n, p)| format!("{n}x{p}"))
        .unwrap_or_default();
    for &(n, p) in &shapes {
        let ds = correlated(
            CorrelatedSpec { n, p, rho: 0.5, nnz: (p / 20).max(1), snr: 8.0 },
            42,
        );
        let shape = format!("{n}x{p}");
        bench_xtr(&shape, &ds.design, &widths, warmup, reps, &mut rows);
        bench_gram(&shape, &ds.design, warmup, reps, &mut rows);
    }

    // ---- headline acceptance metrics ----
    let active = simd::isa();
    let vector_xtr_speedup = (active != KernelIsa::Scalar)
        .then(|| {
            rows.iter()
                .filter(|r| {
                    r.kernel == "xtr_panel" && r.shape == largest && r.isa == active.as_str()
                })
                .map(|r| r.speedup_vs_scalar_f64)
                .next_back()
        })
        .flatten();
    let mixed_gram_speedup = rows
        .iter()
        .filter(|r| r.kernel == "gram_pairs" && r.shape == largest && r.precision == "mixed")
        .map(|r| r.speedup_vs_scalar_f64)
        .next_back();
    // the ≥2× / ≥1.5× bars only bind at full scale on a vector host;
    // vacuous cells pass so the smoke gate runs anywhere
    let xtr_ok = match (scale, vector_xtr_speedup) {
        (Scale::Full, Some(s)) => s >= 2.0,
        _ => true,
    };
    let gram_ok = match (scale, mixed_gram_speedup, active) {
        (Scale::Full, Some(s), a) if a != KernelIsa::Scalar => s >= 1.5,
        _ => true,
    };

    // ---- report ----
    let mut t = Table::new(&[
        "kernel", "shape", "isa", "precision", "B", "median_us", "Mitem_per_s", "speedup",
    ]);
    for r in &rows {
        t.row(vec![
            r.kernel.clone(),
            r.shape.clone(),
            r.isa.clone(),
            r.precision.clone(),
            r.n_rhs.to_string(),
            format!("{:.1}", r.micros),
            format!("{:.1}", r.mitems_per_s),
            format!("{:.2}x", r.speedup_vs_scalar_f64),
        ]);
    }
    let md = write_markdown("simd", "kernel_floor", &t)?;

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .with("kernel", r.kernel.as_str())
                .with("shape", r.shape.as_str())
                .with("isa", r.isa.as_str())
                .with("precision", r.precision.as_str())
                .with("n_rhs", r.n_rhs)
                .with("median_us", r.micros)
                .with("mitems_per_s", r.mitems_per_s)
                .with("speedup_vs_scalar_f64", r.speedup_vs_scalar_f64)
        })
        .collect();
    let json = Json::obj()
        .with("bench", "simd")
        .with(
            "scale",
            match scale {
                Scale::Smoke => "smoke",
                Scale::Full => "full",
            },
        )
        .with("active_isa", active.as_str())
        .with("detected_isa", simd::detect().as_str())
        .with(
            "vector_xtr_speedup",
            vector_xtr_speedup.map_or(Json::Null, Json::from),
        )
        .with(
            "mixed_gram_speedup",
            mixed_gram_speedup.map_or(Json::Null, Json::from),
        )
        .with("vector_xtr_ok", xtr_ok)
        .with("mixed_gram_ok", gram_ok)
        .with("rows", Json::Arr(jrows));

    let dir = results_dir().join("simd");
    ensure_dir(&dir)?;
    let json_path = dir.join("BENCH_simd.json");
    std::fs::write(&json_path, json.render())?;
    let mut outputs = vec![json_path, md];
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_simd.json");
        std::fs::write(&root, json.render())?;
        outputs.push(root);
    }

    eprintln!(
        "[simd] active isa {} · vector xtr {} · mixed gram {}",
        active.as_str(),
        vector_xtr_speedup.map_or("n/a (scalar host)".to_string(), |s| format!("{s:.2}x")),
        mixed_gram_speedup.map_or("n/a".to_string(), |s| format!("{s:.2}x")),
    );
    if !xtr_ok || !gram_ok {
        anyhow::bail!(
            "simd kernel floor below acceptance bars (vector xtr ok={xtr_ok}, mixed gram ok={gram_ok}); see BENCH_simd.json"
        );
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_runs_and_persists_json() {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_simd_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let out = run_simd(Scale::Smoke).unwrap();
        assert!(!out.is_empty());
        for p in &out {
            assert!(p.exists(), "{}", p.display());
        }
        let raw = std::fs::read_to_string(&out[0]).unwrap();
        assert!(raw.contains("\"bench\":\"simd\""));
        assert!(raw.contains("xtr_panel"));
        assert!(raw.contains("gram_pairs"));
        assert!(raw.contains("\"active_isa\""));
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
