//! Benchmark layer: the benchopt-like black-box harness (§3 "How to do a
//! fair comparison between solvers?"), experiment runners for every paper
//! figure/table, and result emitters.

pub mod batch_bench;
pub mod capability;
pub mod figures;
pub mod glm_bench;
pub mod gram_bench;
pub mod group_bench;
pub mod harness;
pub mod kernel_bench;
pub mod path_bench;
pub mod report;
pub mod scenario;
pub mod simd_bench;

pub use harness::{black_box_curve, budget_schedule, BenchPoint, SolverCurve};
