//! Bench scenario `batch`: simultaneous many-fit batching
//! ([`crate::solver::solve_batch`]) measured against the sequential
//! baseline (B independent scalar solves) over a B × shape × density
//! grid, with per-stage flop attribution (CD epochs vs Gram assembly vs
//! multi-RHS panel passes) from [`crate::solver::InnerProfile`].
//!
//! What the JSON certifies (ISSUE 9 acceptance):
//! - `speedup` per cell: sequential wall time / batched wall time for the
//!   same B sibling fits — the headline cell is dense `n=10^4, p=10^3`
//!   at `B >= 8`, where batching must report `>= 2x` (Full scale);
//! - `max_obj_gap` per cell: worst batched-vs-sequential objective gap
//!   across members, `<= 1e-12` everywhere (each member is in fact
//!   bit-identical to its scalar run — the gap is recorded as evidence);
//! - `panel_ratio` per cell: share of modelled work done by the panel
//!   kernel — the amortisation diagnostic (grows with B);
//! - `thread_invariant`: one batched cell re-run under thread budgets
//!   {1, 2, 4} produces bit-identical coefficients (ordered reductions).
//!
//! Results land in `results/batch/` and — the perf-trajectory anchor —
//! `BENCH_batch.json` at the repo root (skipped when `SKGLM_RESULTS`
//! redirects outputs, e.g. under `cargo test`).

use crate::bench::figures::Scale;
use crate::bench::report::{ensure_dir, results_dir, write_markdown};
use crate::data::{correlated, sparse, CorrelatedSpec, Dataset, SparseSpec};
use crate::datafit::Quadratic;
use crate::estimators::linear::quadratic_lambda_max;
use crate::penalty::{BatchPenalty, L1};
use crate::solver::{solve, solve_batch, BatchFit, SolverOpts};
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;
use std::path::PathBuf;
use std::time::Instant;

/// One (shape, B) measurement: batched vs sequential sibling λ-fits.
#[derive(Clone, Debug)]
pub struct BatchBenchRow {
    /// workload shape, e.g. `d2000x500` or `s5000x20000@1e-3`
    pub shape: String,
    /// batch width (number of sibling fits solved simultaneously)
    pub b: usize,
    pub batch_wall_s: f64,
    pub seq_wall_s: f64,
    /// sequential wall / batched wall (>1 ⇒ batching wins)
    pub speedup: f64,
    /// worst per-member |obj_batch - obj_seq| across the batch
    pub max_obj_gap: f64,
    /// batched run: modelled CD-epoch flops
    pub epoch_flops: f64,
    /// batched run: modelled Gram-assembly flops
    pub assembly_flops: f64,
    /// batched run: modelled multi-RHS panel flops
    pub panel_flops: f64,
    /// panel share of the batched run's modelled work
    pub panel_ratio: f64,
    /// shared outer iterations of the batched loop
    pub n_outer: usize,
    /// total CD epochs across all batch members
    pub epochs: usize,
}

/// Sibling λ grid for a batch of width `b`: a geometric sweep inside
/// `[0.02, 0.3] * λ_max` — the FaSTGLZ regularisation-grid scenario.
fn sibling_lambdas(lam_max: f64, b: usize) -> Vec<f64> {
    if b == 1 {
        return vec![lam_max * 0.1];
    }
    let (hi, lo) = (0.3f64, 0.02f64);
    let step = (lo / hi).powf(1.0 / (b - 1) as f64);
    (0..b).map(|k| lam_max * hi * step.powi(k as i32)).collect()
}

/// Lasso objective `0.5/n ||y - X beta||^2 + lam ||beta||_1` in the
/// solver's own arithmetic (parity evidence between the two runs).
fn lasso_objective(ds: &Dataset, beta: &[f64], lam: f64) -> f64 {
    let n = ds.design.nrows();
    let mut xb = vec![0.0; n];
    ds.design.matvec(beta, &mut xb);
    let rss: f64 = ds.y.iter().zip(&xb).map(|(yi, xi)| (yi - xi) * (yi - xi)).sum();
    let l1: f64 = beta.iter().map(|v| v.abs()).sum();
    0.5 * rss / n as f64 + lam * l1
}

fn bench_cell(ds: &Dataset, shape: &str, b: usize, opts: &SolverOpts) -> BatchBenchRow {
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let lams = sibling_lambdas(lam_max, b);

    // batched: one multi-RHS solve over all B siblings
    let fits: Vec<BatchFit> =
        lams.iter().map(|&l| BatchFit::new(BatchPenalty::L1(L1::new(l)))).collect();
    let t0 = Instant::now();
    let out = solve_batch(&ds.design, &ds.y, fits, opts, None, None);
    let batch_wall_s = t0.elapsed().as_secs_f64();

    // sequential baseline: the same B fits, one scalar solve at a time
    let t1 = Instant::now();
    let seq: Vec<crate::solver::FitResult> = lams
        .iter()
        .map(|&l| {
            let mut f = Quadratic::new();
            solve(&ds.design, &ds.y, &mut f, &L1::new(l), opts, None, None)
        })
        .collect();
    let seq_wall_s = t1.elapsed().as_secs_f64();

    let mut max_obj_gap = 0.0f64;
    for ((m, s), &lam) in out.members.iter().zip(&seq).zip(&lams) {
        let ob = lasso_objective(ds, &m.result.beta, lam);
        let os = lasso_objective(ds, &s.beta, lam);
        max_obj_gap = max_obj_gap.max((ob - os).abs());
    }

    let p = &out.profile;
    BatchBenchRow {
        shape: shape.to_string(),
        b,
        batch_wall_s,
        seq_wall_s,
        speedup: seq_wall_s / batch_wall_s.max(1e-12),
        max_obj_gap,
        epoch_flops: p.epoch_flops,
        assembly_flops: p.gram_assembly_flops,
        panel_flops: p.panel_flops,
        panel_ratio: p.panel_flop_ratio(),
        n_outer: out.n_outer,
        epochs: out.members.iter().map(|m| m.result.n_epochs).sum(),
    }
}

/// Bit-invariance across kernel thread budgets: the batched panel kernel
/// uses ordered per-RHS reductions, so coefficients must not drift with
/// the thread count.
fn thread_invariance_check(ds: &Dataset, b: usize, opts: &SolverOpts) -> bool {
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let lams = sibling_lambdas(lam_max, b);
    let run = || {
        let fits: Vec<BatchFit> =
            lams.iter().map(|&l| BatchFit::new(BatchPenalty::L1(L1::new(l)))).collect();
        solve_batch(&ds.design, &ds.y, fits, opts, None, None)
    };
    let before = crate::linalg::parallel::thread_budget();
    let mut reference: Option<Vec<u64>> = None;
    let mut ok = true;
    for budget in [1usize, 2, 4] {
        crate::linalg::parallel::set_thread_budget(budget);
        let out = run();
        let bits: Vec<u64> = out
            .members
            .iter()
            .flat_map(|m| m.result.beta.iter().map(|v| v.to_bits()))
            .collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => ok &= r == &bits,
        }
    }
    crate::linalg::parallel::set_thread_budget(before);
    ok
}

/// Run the batched-vs-sequential grid and persist `BENCH_batch.json`.
pub fn run_batch(scale: Scale) -> Result<Vec<PathBuf>> {
    // (n, p, batch widths): the Full dense 10^4 x 10^3 cell at B >= 8 is
    // the ISSUE 9 acceptance configuration
    let dense_shapes: Vec<(usize, usize, Vec<usize>)> = match scale {
        Scale::Smoke => vec![(400, 120, vec![1, 2, 8])],
        Scale::Full => vec![
            (2000, 500, vec![1, 2, 8, 33]),
            (10_000, 1_000, vec![8, 16]),
        ],
    };
    let sparse_shapes: Vec<(usize, usize, f64, Vec<usize>)> = match scale {
        Scale::Smoke => vec![(800, 2000, 5e-3, vec![2, 8])],
        Scale::Full => vec![(5000, 20_000, 1e-3, vec![2, 8, 33])],
    };

    let opts = SolverOpts::default().with_tol(1e-10);
    let mut rows: Vec<BatchBenchRow> = Vec::new();

    for (n, p, widths) in &dense_shapes {
        let ds = correlated(
            CorrelatedSpec { n: *n, p: *p, rho: 0.5, nnz: (p / 20).max(1), snr: 8.0 },
            42,
        );
        for &b in widths {
            rows.push(bench_cell(&ds, &format!("d{n}x{p}"), b, &opts));
        }
    }
    for (n, p, density, widths) in &sparse_shapes {
        let ds = sparse(
            "batch",
            SparseSpec {
                n: *n,
                p: *p,
                density: *density,
                support_frac: 0.002,
                snr: 5.0,
                binary: false,
            },
            7,
        );
        for &b in widths {
            rows.push(bench_cell(&ds, &format!("s{n}x{p}@{density:e}"), b, &opts));
        }
    }

    // bit-invariance cell: small enough to run thrice, wide enough to
    // exercise the multi-RHS panel
    let inv_ds = correlated(CorrelatedSpec { n: 300, p: 100, rho: 0.5, nnz: 6, snr: 8.0 }, 19);
    let thread_invariant = thread_invariance_check(&inv_ds, 8, &opts);

    let parity_ok = rows.iter().all(|r| r.max_obj_gap <= 1e-12);
    // acceptance headline: best speedup on the dense 10^4 x 10^3 cell at
    // B >= 8 (Full scale only; smoke shapes are too small to certify)
    let headline = rows
        .iter()
        .filter(|r| r.shape == "d10000x1000" && r.b >= 8)
        .map(|r| r.speedup)
        .fold(f64::NAN, f64::max);
    let headline_ok = match scale {
        Scale::Full => headline >= 2.0,
        Scale::Smoke => true,
    };

    // ---- report ----
    let mut t = Table::new(&[
        "shape", "B", "batch_s", "seq_s", "speedup", "obj_gap", "epoch_Mflop", "asm_Mflop",
        "panel_Mflop", "panel_ratio", "outer", "epochs",
    ]);
    for r in &rows {
        t.row(vec![
            r.shape.clone(),
            r.b.to_string(),
            format!("{:.4}", r.batch_wall_s),
            format!("{:.4}", r.seq_wall_s),
            format!("{:.2}x", r.speedup),
            format!("{:.2e}", r.max_obj_gap),
            format!("{:.2}", r.epoch_flops / 1e6),
            format!("{:.2}", r.assembly_flops / 1e6),
            format!("{:.2}", r.panel_flops / 1e6),
            format!("{:.3}", r.panel_ratio),
            r.n_outer.to_string(),
            r.epochs.to_string(),
        ]);
    }
    let md = write_markdown("batch", "batched_vs_sequential", &t)?;

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .with("shape", r.shape.as_str())
                .with("b", r.b)
                .with("batch_wall_s", r.batch_wall_s)
                .with("seq_wall_s", r.seq_wall_s)
                .with("speedup", r.speedup)
                .with("max_obj_gap", r.max_obj_gap)
                .with("epoch_flops", r.epoch_flops)
                .with("assembly_flops", r.assembly_flops)
                .with("panel_flops", r.panel_flops)
                .with("panel_ratio", r.panel_ratio)
                .with("n_outer", r.n_outer)
                .with("epochs", r.epochs)
        })
        .collect();
    let json = Json::obj()
        .with("bench", "batch")
        .with(
            "scale",
            match scale {
                Scale::Smoke => "smoke",
                Scale::Full => "full",
            },
        )
        .with("rows", Json::Arr(jrows))
        .with("parity_ok", parity_ok)
        .with("thread_invariant", thread_invariant)
        .with("headline_speedup", if headline.is_nan() { 0.0 } else { headline })
        .with("headline_ok", headline_ok);

    let dir = results_dir().join("batch");
    ensure_dir(&dir)?;
    let json_path = dir.join("BENCH_batch.json");
    std::fs::write(&json_path, json.render())?;
    let mut outputs = vec![json_path, md];
    // the repo-root trajectory file (skipped when results are redirected,
    // e.g. by tests)
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_batch.json");
        std::fs::write(&root, json.render())?;
        outputs.push(root);
    }

    if let Some(best) = rows.iter().max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap()) {
        eprintln!(
            "[batch] {} B={}: batched = {:.2}x sequential wall, panel share {:.1}% \
             (parity <= 1e-12: {parity_ok}, thread bit-invariant: {thread_invariant})",
            best.shape, best.b, best.speedup, 100.0 * best.panel_ratio
        );
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_runs_and_persists_json() {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_batch_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let out = run_batch(Scale::Smoke).unwrap();
        assert!(!out.is_empty());
        for p in &out {
            assert!(p.exists(), "{}", p.display());
        }
        let raw = std::fs::read_to_string(&out[0]).unwrap();
        assert!(raw.contains("\"bench\":\"batch\""));
        assert!(raw.contains("\"parity_ok\":true"), "objective parity failed: {raw}");
        assert!(raw.contains("\"thread_invariant\":true"), "thread drift: {raw}");
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn sibling_lambda_grid_is_descending_and_sized() {
        assert_eq!(sibling_lambdas(1.0, 1).len(), 1);
        let g = sibling_lambdas(2.0, 8);
        assert_eq!(g.len(), 8);
        for w in g.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!((g[0] - 2.0 * 0.3).abs() < 1e-12);
        assert!((g[7] - 2.0 * 0.02).abs() < 1e-9);
    }
}
