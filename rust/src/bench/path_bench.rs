//! Bench scenario `pathsched`: per-λ cold fits vs the warm-started path
//! scheduler on the Figure-1 dataset.
//!
//! The cold strategy is what the old closed-enum fit service did — every
//! λ an independent fit from β = 0. The warm strategy is the coordinator
//! tentpole: one [`crate::coordinator::FitScheduler`] path job sweeping
//! the same grid with warm-started coefficients, persistent working-set
//! size and a per-λ gap-safe screening pass. Both run on **one** worker,
//! so the measured win is algorithmic, not parallelism. Output lands in
//! `results/pathsched/` (see EXPERIMENTS.md §pathsched).

use crate::bench::figures::Scale;
use crate::bench::report::write_markdown;
use crate::coordinator::{specs, FitScheduler, JobEvent};
use crate::data::{correlated, CorrelatedSpec};
use crate::estimators::path::geometric_grid;
use crate::solver::{ContinuationState, SolverOpts};
use crate::util::table::Table;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Outcome of one cold-vs-warm comparison.
pub struct PathSchedComparison {
    pub points: usize,
    pub cold_epochs: usize,
    pub warm_epochs: usize,
    pub cold_time: f64,
    pub warm_time: f64,
    /// total features certified inactive across the warm sweep
    pub warm_screened: usize,
}

impl PathSchedComparison {
    /// CD-epoch speedup of warm path scheduling over cold per-λ fits.
    pub fn epoch_speedup(&self) -> f64 {
        self.cold_epochs as f64 / self.warm_epochs.max(1) as f64
    }
}

/// Run the comparison on the Figure-1 dataset at `scale_frac` of the
/// paper's (n = 1000, p = 2000) size, over a geometric grid of `points`
/// λ ratios down to `min_ratio`.
pub fn compare_cold_vs_warm(
    scale_frac: f64,
    points: usize,
    min_ratio: f64,
    tol: f64,
    seed: u64,
) -> PathSchedComparison {
    let ds = Arc::new(correlated(CorrelatedSpec::figure1(scale_frac), seed));
    let ratios = geometric_grid(min_ratio, points);
    let opts = SolverOpts::default().with_tol(tol);
    let spec = specs::lasso(1.0);
    let lambda_max = spec.lambda_max(&ds.design, &ds.y);

    // cold: every λ an independent fit from zero (fresh state per point)
    let t0 = Instant::now();
    let mut cold_epochs = 0;
    for &ratio in &ratios {
        let point_spec = spec.at_lambda(lambda_max * ratio);
        let mut state = ContinuationState::default();
        let fit = point_spec.solve(&ds.design, &ds.y, &opts, &mut state, None, None);
        cold_epochs += fit.n_epochs;
    }
    let cold_time = t0.elapsed().as_secs_f64();

    // warm: one scheduler path job on one worker, streamed per-λ
    let sched = FitScheduler::start(1);
    let t1 = Instant::now();
    sched.submit_path(Arc::clone(&ds), specs::lasso(1.0), ratios.clone(), opts);
    let mut warm_epochs = 0;
    let mut warm_screened = 0;
    loop {
        match sched.events.recv().expect("scheduler died") {
            JobEvent::PathPoint(p) => {
                warm_epochs += p.epochs;
                warm_screened += p.n_screened;
            }
            JobEvent::PathDone(_) => break,
            JobEvent::FitDone(_) => {}
            JobEvent::Failed { job_id, message } => {
                panic!("path job {job_id} failed: {message}")
            }
            JobEvent::Cancelled { job_id, .. } => panic!("path job {job_id} cancelled"),
            JobEvent::SchedulerDown => panic!("scheduler died mid-path"),
        }
    }
    let warm_time = t1.elapsed().as_secs_f64();
    sched.shutdown();

    PathSchedComparison {
        points,
        cold_epochs,
        warm_epochs,
        cold_time,
        warm_time,
        warm_screened,
    }
}

/// Experiment runner (`skglm exp pathsched [--full]`): writes the
/// comparison table under `results/pathsched/`.
pub fn run_pathsched(scale: Scale) -> Result<Vec<PathBuf>> {
    let (frac, points, tol) = match scale {
        Scale::Smoke => (0.12, 10, 1e-6),
        Scale::Full => (1.0, 30, 1e-8),
    };
    let c = compare_cold_vs_warm(frac, points, 1e-2, tol, 42);
    let mut t = Table::new(&["strategy", "points", "cd_epochs", "screened", "wall_s", "epoch_speedup"]);
    t.row(vec![
        "cold fit per λ".to_string(),
        c.points.to_string(),
        c.cold_epochs.to_string(),
        "0".to_string(),
        format!("{:.3}", c.cold_time),
        "1.00x".to_string(),
    ]);
    t.row(vec![
        "warm path scheduler".to_string(),
        c.points.to_string(),
        c.warm_epochs.to_string(),
        c.warm_screened.to_string(),
        format!("{:.3}", c.warm_time),
        format!("{:.2}x", c.epoch_speedup()),
    ]);
    eprintln!("[pathsched] cold {} epochs / {:.3}s  vs  warm {} epochs / {:.3}s ({:.2}x)",
        c.cold_epochs, c.cold_time, c.warm_epochs, c.warm_time, c.epoch_speedup());
    Ok(vec![write_markdown("pathsched", "fig1_cold_vs_warm", &t)?])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_path_scheduling_beats_cold_fits() {
        let c = compare_cold_vs_warm(0.08, 8, 2e-2, 1e-6, 7);
        assert_eq!(c.points, 8);
        assert!(c.cold_epochs > 0 && c.warm_epochs > 0);
        assert!(
            c.warm_epochs < c.cold_epochs,
            "warm ({}) should need fewer CD epochs than cold ({})",
            c.warm_epochs,
            c.cold_epochs
        );
    }
}
