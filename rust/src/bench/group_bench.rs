//! Bench scenario `groups`: the block-coordinate engine on group-sparse
//! workloads, measured against (a) scalar CD on the *ungrouped* ℓ1
//! relaxation of the same data and (b) the full-gradient proximal
//! baseline (FISTA with the block prox), across group-size × active-density
//! grids.
//!
//! Per workload × λ the runner records wall time to each solver's own
//! stopping criterion, the final objective, group-support F1 against the
//! planted groups, and — for the two solvers minimising the *same* convex
//! group objective (block CD vs prox gradient) — the relative objective
//! gap, with acceptance bar `rel_gap ≤ 1e-6` on every grid point. Results
//! land in `results/groups/` and — the perf-trajectory anchor —
//! `BENCH_groups.json` at the repo root (skipped when `SKGLM_RESULTS`
//! redirects outputs, e.g. under `cargo test`).

use crate::bench::figures::Scale;
use crate::bench::kernel_bench::time_it;
use crate::bench::report::{ensure_dir, results_dir, write_markdown};
use crate::data::{grouped_correlated, GroupedSpec};
use crate::estimators::group_lambda_max;
use crate::estimators::linear::quadratic_lambda_max;
use crate::penalty::{GroupLasso, GroupMcp, L1};
use crate::solver::baselines::group_pgd::solve_group_pgd;
use crate::solver::partition::BlockPartition;
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// One solved (workload, λ, solver) grid point.
#[derive(Clone, Debug)]
pub struct GroupBenchRow {
    /// workload shape, e.g. `200x400/g8@0.1`
    pub shape: String,
    pub lambda_ratio: f64,
    /// `block_cd` | `block_cd_mcp` | `scalar_cd_l1` | `prox_grad`
    pub solver: String,
    pub millis: f64,
    pub objective: f64,
    /// (objective − best) / |best| across the solvers sharing the convex
    /// group objective; NaN for solvers on a different objective
    pub rel_gap: f64,
    /// F1 of recovered groups vs planted groups
    pub group_f1: f64,
    pub iters: usize,
}

fn group_f1(recovered: &[usize], planted: &[usize]) -> f64 {
    if recovered.is_empty() && planted.is_empty() {
        return 1.0;
    }
    let tp = recovered.iter().filter(|g| planted.contains(g)).count() as f64;
    let prec = if recovered.is_empty() { 0.0 } else { tp / recovered.len() as f64 };
    let rec = if planted.is_empty() { 0.0 } else { tp / planted.len() as f64 };
    if prec + rec == 0.0 {
        0.0
    } else {
        2.0 * prec * rec / (prec + rec)
    }
}

/// Groups whose planted coefficients are nonzero.
fn planted_groups(beta_true: &[f64], part: &BlockPartition) -> Vec<usize> {
    (0..part.n_blocks())
        .filter(|&b| part.coords(b).iter().any(|&j| beta_true[j] != 0.0))
        .collect()
}

/// Scalar support → group support (a group counts when any member is
/// active) for the ungrouped ℓ1 baseline.
fn scalar_to_groups(beta: &[f64], part: &BlockPartition) -> Vec<usize> {
    (0..part.n_blocks())
        .filter(|&b| part.coords(b).iter().any(|&j| beta[j] != 0.0))
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn run_workload(
    shape: &str,
    ds: &crate::data::Dataset,
    part: &Arc<BlockPartition>,
    lam_ratios: &[f64],
    warmup: usize,
    reps: usize,
    gamma: f64,
    rows: &mut Vec<GroupBenchRow>,
) {
    let planted = planted_groups(&ds.beta_true, part);
    let lam_max = group_lambda_max(&ds.design, &ds.y, part, None);
    let l1_lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    for &ratio in lam_ratios {
        let lam = lam_max * ratio;
        let opts = crate::solver::SolverOpts::default().with_tol(1e-9);

        // block CD on the convex group Lasso
        let mut cd_res = None;
        let cd_secs = time_it(warmup, reps, || {
            cd_res = Some(
                crate::estimators::group::group_lasso(lam, Arc::clone(part))
                    .with_tol(1e-9)
                    .fit(&ds.design, &ds.y),
            );
        });
        let cd = cd_res.expect("timed at least once");

        // prox-gradient on the same objective
        let mut pgd_res = None;
        let pgd_secs = time_it(warmup, reps, || {
            pgd_res = Some(solve_group_pgd(
                &ds.design,
                &ds.y,
                part,
                &GroupLasso::new(lam),
                100_000,
                1e-10,
                true,
            ));
        });
        let pgd = pgd_res.expect("timed at least once");

        // scalar CD on the ungrouped ℓ1 relaxation (its own objective)
        let mut l1_res = None;
        let l1_secs = time_it(warmup, reps, || {
            let mut f = crate::datafit::Quadratic::new();
            l1_res = Some(crate::solver::solve(
                &ds.design,
                &ds.y,
                &mut f,
                &L1::new(l1_lam_max * ratio),
                &opts,
                None,
                None,
            ));
        });
        let l1 = l1_res.expect("timed at least once");

        // non-convex group MCP through the same engine (its own objective)
        let mut mcp_res = None;
        let mcp_secs = time_it(warmup, reps, || {
            mcp_res = Some(
                crate::estimators::group::GroupEstimator::from_parts(
                    GroupMcp::new(lam, gamma),
                    Arc::clone(part),
                    opts.clone(),
                )
                .fit(&ds.design, &ds.y),
            );
        });
        let mcp = mcp_res.expect("timed at least once");

        let best = cd.result.objective.min(pgd.objective);
        let denom = best.abs().max(1e-12);
        rows.push(GroupBenchRow {
            shape: shape.to_string(),
            lambda_ratio: ratio,
            solver: "block_cd".into(),
            millis: cd_secs * 1e3,
            objective: cd.result.objective,
            rel_gap: (cd.result.objective - best) / denom,
            group_f1: group_f1(&cd.group_support(), &planted),
            iters: cd.result.n_epochs,
        });
        rows.push(GroupBenchRow {
            shape: shape.to_string(),
            lambda_ratio: ratio,
            solver: "prox_grad".into(),
            millis: pgd_secs * 1e3,
            objective: pgd.objective,
            rel_gap: (pgd.objective - best) / denom,
            group_f1: group_f1(&scalar_to_groups(&pgd.v, part), &planted),
            iters: pgd.iters,
        });
        rows.push(GroupBenchRow {
            shape: shape.to_string(),
            lambda_ratio: ratio,
            solver: "scalar_cd_l1".into(),
            millis: l1_secs * 1e3,
            objective: l1.objective,
            rel_gap: f64::NAN,
            group_f1: group_f1(&scalar_to_groups(&l1.beta, part), &planted),
            iters: l1.n_epochs,
        });
        rows.push(GroupBenchRow {
            shape: shape.to_string(),
            lambda_ratio: ratio,
            solver: "block_cd_mcp".into(),
            millis: mcp_secs * 1e3,
            objective: mcp.result.objective,
            rel_gap: f64::NAN,
            group_f1: group_f1(&mcp.group_support(), &planted),
            iters: mcp.result.n_epochs,
        });
    }
}

/// Run the group grid and persist `BENCH_groups.json`.
pub fn run_groups(scale: Scale) -> Result<Vec<PathBuf>> {
    // (n, p, group_size, active fraction of groups) × λ-ratio grid
    #[allow(clippy::type_complexity)]
    let (shapes, lam_ratios, warmup, reps): (Vec<(usize, usize, usize, f64)>, Vec<f64>, usize, usize) =
        match scale {
            Scale::Smoke => (vec![(80, 160, 8, 0.1)], vec![0.2], 1, 3),
            Scale::Full => (
                vec![
                    (400, 1600, 5, 0.05),
                    (400, 1600, 20, 0.05),
                    (400, 1600, 20, 0.2),
                    (1000, 4000, 40, 0.05),
                ],
                vec![0.2, 0.05],
                2,
                5,
            ),
        };

    let mut rows: Vec<GroupBenchRow> = Vec::new();
    for &(n, p, group_size, active_frac) in &shapes {
        let n_groups = p / group_size;
        let active = ((n_groups as f64) * active_frac).round().max(1.0) as usize;
        let (ds, part) = grouped_correlated(
            GroupedSpec { n, p, group_size, active_groups: active, rho: 0.5, snr: 8.0 },
            42,
        );
        let shape = format!("{n}x{p}/g{group_size}@{active_frac}");
        // MCP semi-convexity: γ > 1/min L_b ≈ 1/group_size (AR(1) columns
        // have ‖X_j‖² ≈ n), so γ = 3 is comfortably valid
        run_workload(&shape, &ds, &part, &lam_ratios, warmup, reps, 3.0, &mut rows);
    }

    // ---- report ----
    let mut t = Table::new(&[
        "shape", "lambda_ratio", "solver", "median_ms", "objective", "rel_gap", "group_f1",
        "iters",
    ]);
    for r in &rows {
        t.row(vec![
            r.shape.clone(),
            format!("{:.3}", r.lambda_ratio),
            r.solver.clone(),
            format!("{:.2}", r.millis),
            format!("{:.9e}", r.objective),
            if r.rel_gap.is_nan() { "-".into() } else { format!("{:.2e}", r.rel_gap) },
            format!("{:.3}", r.group_f1),
            r.iters.to_string(),
        ]);
    }
    let md = write_markdown("groups", "block_cd_vs_baselines", &t)?;

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .with("shape", r.shape.as_str())
                .with("lambda_ratio", r.lambda_ratio)
                .with("solver", r.solver.as_str())
                .with("median_ms", r.millis)
                .with("objective", r.objective)
                .with("rel_gap", if r.rel_gap.is_nan() { -1.0 } else { r.rel_gap })
                .with("group_f1", r.group_f1)
                .with("iters", r.iters)
        })
        .collect();
    let json = Json::obj()
        .with("bench", "groups")
        .with(
            "scale",
            match scale {
                Scale::Smoke => "smoke",
                Scale::Full => "full",
            },
        )
        .with("agreement_bar", 1e-6)
        .with("rows", Json::Arr(jrows));

    let dir = results_dir().join("groups");
    ensure_dir(&dir)?;
    let json_path = dir.join("BENCH_groups.json");
    std::fs::write(&json_path, json.render())?;
    let mut outputs = vec![json_path, md];
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_groups.json");
        std::fs::write(&root, json.render())?;
        outputs.push(root);
    }

    // headline: convex agreement + speedup vs the prox-gradient baseline
    let worst_gap = rows
        .iter()
        .filter(|r| !r.rel_gap.is_nan())
        .map(|r| r.rel_gap)
        .fold(0.0f64, f64::max);
    eprintln!("[groups] worst block-CD/prox-grad relative objective gap: {worst_gap:.2e} (bar 1e-6)");
    let (mut cd_ms, mut pgd_ms) = (0.0, 0.0);
    for r in &rows {
        match r.solver.as_str() {
            "block_cd" => cd_ms += r.millis,
            "prox_grad" => pgd_ms += r.millis,
            _ => {}
        }
    }
    if cd_ms > 0.0 {
        eprintln!(
            "[groups] block CD {cd_ms:.1}ms total vs prox gradient {pgd_ms:.1}ms ({:.2}x)",
            pgd_ms / cd_ms
        );
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_runs_meets_agreement_bar_and_persists_json() {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_groups_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let out = run_groups(Scale::Smoke).unwrap();
        assert!(!out.is_empty());
        for p in &out {
            assert!(p.exists(), "{}", p.display());
        }
        let raw = std::fs::read_to_string(&out[0]).unwrap();
        assert!(raw.contains("\"bench\":\"groups\""));
        assert!(raw.contains("block_cd"));
        assert!(raw.contains("prox_grad"));
        assert!(raw.contains("scalar_cd_l1"));
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn group_f1_edge_cases() {
        assert_eq!(group_f1(&[], &[]), 1.0);
        assert_eq!(group_f1(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(group_f1(&[1], &[2]), 0.0);
        let f1 = group_f1(&[1, 2, 3], &[1, 2]);
        assert!(f1 > 0.7 && f1 < 1.0);
    }
}
