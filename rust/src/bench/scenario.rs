//! Declarative scenario conformance corpus + runner (`skglm conform`,
//! `exp scenarios`).
//!
//! One harness certifies the full (datafit × penalty × shape × density ×
//! seed) matrix through the **real** [`FitScheduler`] path machinery —
//! the same warm sweeps, caches and screening the CLI and benches use —
//! instead of per-PR ad-hoc integration tests. Each [`Scenario`] runs an
//! A/B variant plan:
//!
//! - **baseline**: residual inner engine, thread budget 1, a 3-λ warm
//!   [`Job::Path`](crate::coordinator::Job::Path) sweep;
//! - **cold**: each λ re-solved in a fresh scheduler (no continuation,
//!   no coefficient cache) — warm == cold λ-by-λ on the objective for
//!   convex scenarios;
//! - **engines**: the same warm sweep under `inner ∈ {gram, auto}`
//!   (quadratic datafits only — the Gram contract's gate) — cross-engine
//!   agreement ≤ 1e-10 for convex scenarios, objective agreement for
//!   non-convex ones (engines may round to different critical points);
//! - **threads**: the same warm sweep under thread budget 4 —
//!   bit-identical coefficients (the PR-2 kernel-engine invariant);
//! - **batched**: two identical sibling paths submitted at batch
//!   priority behind a blocker, fusing into one multi-RHS panel job
//!   (batchable specs only) — every member's objectives must agree with
//!   the baseline λ-by-λ;
//! - **precision** (ISSUE 10): a scenario may declare `precision`
//!   (`f64` | `f32` | `mixed`) — every variant then runs its full-design
//!   passes at that precision and the certificate bar is floored at
//!   [`Precision::tol_floor`]. Reduced-precision scenarios also solve an
//!   f64 reference run; the objective deviation is recorded as a metric
//!   (`precision_ref_dev`), not gated — the floored certificate is the
//!   contract, closeness to f64 is diagnostic.
//!
//! Per-scenario oracles additionally check the solver's own certificate
//! (duality gap / stationarity, [`crate::solver::Certificate`]) against
//! the scenario's declared tolerance (floored by the declared precision)
//! at **every** path point — the
//! residual is read off [`PathPointOutcome`](crate::coordinator::scheduler::PathPointOutcome),
//! never recomputed. Results are emitted in an AgentLab-style schema
//! (`scenario_id`, `outcome: pass|fail|skip`, `objective`, `metrics`,
//! `violations`) to `results/scenarios/` + repo-root
//! `BENCH_scenarios.json` (rolled into `BENCH_SUMMARY.json`).
//!
//! The corpus is declarative: `scenarios.jsonl` at the repo root (one
//! JSON object per line, parsed with [`crate::util::json::Json::parse`])
//! with [`builtin_corpus`] as the compiled-in fallback so the binary is
//! self-contained offline. A scenario whose (datafit, penalty) pair the
//! library does not ship reports `outcome: "skip"` instead of failing —
//! corpora may be shared with other implementations.

use crate::bench::report::{ensure_dir, results_dir};
use crate::coordinator::{specs, FitScheduler, FitSpec, JobEvent};
use crate::data::{
    correlated, grouped_correlated, poisson_correlated, probit_correlated, sparse,
    CorrelatedSpec, Dataset, GroupedSpec, SparseSpec,
};
use crate::linalg::parallel::{set_thread_budget, thread_budget};
use crate::linalg::simd::Precision;
use crate::solver::{InnerEngine, SolverOpts};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One declarative conformance task. Everything needed to build the
/// dataset and the spec deterministically lives here — two runs of the
/// same scenario see bit-identical inputs.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// unique id (`results/scenarios/<id>.json`)
    pub id: String,
    /// quadratic | logistic | poisson | probit | grouped | multitask
    pub datafit: String,
    /// l1 | weighted_l1 | l1l2 | mcp | scad | lq | group_lasso |
    /// weighted_group_lasso | group_mcp | group_scad | l21 | block_mcp
    pub penalty: String,
    pub n: usize,
    pub p: usize,
    /// design density; 1.0 = dense generator, < 1.0 = CSC generator
    pub density: f64,
    pub seed: u64,
    /// smallest λ/λ_max of the 3-point warm grid
    pub lambda_ratio: f64,
    /// declared optimality tolerance (the certificate oracle's bar)
    pub tol: f64,
    /// MCP/SCAD shape (γ)
    pub gamma: f64,
    /// ℓ_q exponent (0 < q < 1)
    pub q: f64,
    /// features per group (grouped datafit)
    pub group_size: usize,
    /// number of tasks (multitask datafit)
    pub n_tasks: usize,
    /// full-design pass precision: f64 | f32 | mixed (ISSUE 10); the
    /// certificate bar is floored at the precision's certified floor
    pub precision: String,
    /// member of the CI smoke subset (`skglm conform --smoke`)
    pub smoke: bool,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            id: String::new(),
            datafit: "quadratic".into(),
            penalty: "l1".into(),
            n: 80,
            p: 120,
            density: 1.0,
            seed: 0,
            lambda_ratio: 0.1,
            tol: 1e-8,
            gamma: 3.0,
            q: 0.5,
            group_size: 5,
            n_tasks: 3,
            precision: "f64".into(),
            smoke: false,
        }
    }
}

impl Scenario {
    /// Parse one corpus line. Unknown keys fail loudly (a typoed field
    /// silently reverting to its default would weaken the oracle it was
    /// meant to tighten); missing optional keys take [`Scenario::default`]s.
    pub fn from_json(j: &Json) -> std::result::Result<Scenario, String> {
        let fields = j.fields().ok_or("scenario line is not a JSON object")?;
        let mut s = Scenario::default();
        let mut saw_id = false;
        for (key, val) in fields {
            let bad = || format!("field {key:?} has the wrong type: {}", val.render());
            match key.as_str() {
                "id" => {
                    s.id = val.as_str().ok_or_else(bad)?.to_string();
                    saw_id = true;
                }
                "datafit" => s.datafit = val.as_str().ok_or_else(bad)?.to_string(),
                "penalty" => s.penalty = val.as_str().ok_or_else(bad)?.to_string(),
                "n" => s.n = val.as_usize().ok_or_else(bad)?,
                "p" => s.p = val.as_usize().ok_or_else(bad)?,
                "density" => s.density = val.as_f64().ok_or_else(bad)?,
                "seed" => s.seed = val.as_usize().ok_or_else(bad)? as u64,
                "lambda_ratio" => s.lambda_ratio = val.as_f64().ok_or_else(bad)?,
                "tol" => s.tol = val.as_f64().ok_or_else(bad)?,
                "gamma" => s.gamma = val.as_f64().ok_or_else(bad)?,
                "q" => s.q = val.as_f64().ok_or_else(bad)?,
                "group_size" => s.group_size = val.as_usize().ok_or_else(bad)?,
                "n_tasks" => s.n_tasks = val.as_usize().ok_or_else(bad)?,
                "precision" => s.precision = val.as_str().ok_or_else(bad)?.to_string(),
                "smoke" => s.smoke = val.as_bool().ok_or_else(bad)?,
                other => return Err(format!("unknown scenario field {other:?}")),
            }
        }
        if !saw_id || s.id.is_empty() {
            return Err("scenario is missing a non-empty \"id\"".into());
        }
        if s.n == 0 || s.p == 0 {
            return Err(format!("{}: n and p must be positive", s.id));
        }
        if !(s.lambda_ratio > 0.0 && s.lambda_ratio < 0.5) {
            return Err(format!("{}: lambda_ratio must be in (0, 0.5)", s.id));
        }
        if !(s.tol > 0.0) {
            return Err(format!("{}: tol must be positive", s.id));
        }
        if Precision::parse(&s.precision).is_none() {
            return Err(format!(
                "{}: precision must be f64|f32|mixed, got {:?}",
                s.id, s.precision
            ));
        }
        Ok(s)
    }

    /// The corpus-line form (defaults included, so rendered corpora are
    /// self-describing).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("id", self.id.as_str())
            .with("datafit", self.datafit.as_str())
            .with("penalty", self.penalty.as_str())
            .with("n", self.n)
            .with("p", self.p)
            .with("density", self.density)
            .with("seed", self.seed)
            .with("lambda_ratio", self.lambda_ratio)
            .with("tol", self.tol)
            .with("gamma", self.gamma)
            .with("q", self.q)
            .with("group_size", self.group_size)
            .with("n_tasks", self.n_tasks)
            .with("precision", self.precision.as_str())
            .with("smoke", self.smoke)
    }
}

/// Parse a JSONL corpus (one scenario per non-blank line). Errors carry
/// the 1-based line number; duplicate ids are rejected.
pub fn parse_corpus(text: &str) -> std::result::Result<Vec<Scenario>, String> {
    let mut out: Vec<Scenario> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let s = Scenario::from_json(&j).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        if out.iter().any(|o| o.id == s.id) {
            return Err(format!("line {}: duplicate scenario id {:?}", lineno + 1, s.id));
        }
        out.push(s);
    }
    Ok(out)
}

/// Render a corpus back to JSONL (the canonical `scenarios.jsonl` form).
pub fn render_corpus(corpus: &[Scenario]) -> String {
    let mut s = String::new();
    for scn in corpus {
        s.push_str(&scn.to_json().render());
        s.push('\n');
    }
    s
}

/// The compiled-in corpus: ≥ 30 scenarios covering every shipped datafit
/// (quadratic, logistic, poisson, probit, grouped, multitask) × every
/// penalty family (ℓ1, weighted ℓ1, ℓ1+ℓ2, MCP, SCAD, ℓ_q, group Lasso,
/// weighted group Lasso, group MCP, group SCAD, ℓ2,1, block MCP), dense
/// and sparse designs, several shapes and seeds. `scenarios.jsonl` at the
/// repo root mirrors this list (a test asserts the two stay in sync).
pub fn builtin_corpus() -> Vec<Scenario> {
    let base = Scenario::default;
    let mut c: Vec<Scenario> = Vec::new();

    // ---- quadratic: every scalar penalty, dense + sparse + shapes ----
    c.push(Scenario { id: "quad_l1_dense_a".into(), seed: 0, smoke: true, ..base() });
    c.push(Scenario { id: "quad_l1_dense_b".into(), n: 120, p: 80, seed: 1, lambda_ratio: 0.05, ..base() });
    c.push(Scenario { id: "quad_l1_tall".into(), n: 300, p: 60, seed: 2, ..base() });
    c.push(Scenario { id: "quad_l1_sparse".into(), n: 200, p: 400, density: 0.05, seed: 3, smoke: true, ..base() });
    c.push(Scenario { id: "quad_wl1_dense".into(), penalty: "weighted_l1".into(), seed: 4, smoke: true, ..base() });
    c.push(Scenario { id: "quad_wl1_sparse".into(), penalty: "weighted_l1".into(), n: 200, p: 400, density: 0.05, seed: 5, ..base() });
    c.push(Scenario { id: "quad_enet_dense".into(), penalty: "l1l2".into(), seed: 6, ..base() });
    c.push(Scenario { id: "quad_mcp_dense".into(), penalty: "mcp".into(), seed: 7, smoke: true, ..base() });
    c.push(Scenario { id: "quad_mcp_sparse".into(), penalty: "mcp".into(), n: 200, p: 400, density: 0.05, seed: 8, ..base() });
    c.push(Scenario { id: "quad_scad_dense".into(), penalty: "scad".into(), gamma: 3.7, seed: 9, ..base() });
    c.push(Scenario { id: "quad_scad_wide".into(), penalty: "scad".into(), gamma: 3.7, n: 60, p: 150, seed: 10, ..base() });
    c.push(Scenario { id: "quad_lq_half".into(), penalty: "lq".into(), q: 0.5, lambda_ratio: 0.2, seed: 11, smoke: true, ..base() });
    c.push(Scenario { id: "quad_lq_twothirds".into(), penalty: "lq".into(), q: 0.667, lambda_ratio: 0.2, seed: 12, ..base() });

    // ---- logistic (±1 labels) ----
    c.push(Scenario { id: "logit_l1_dense_a".into(), datafit: "logistic".into(), seed: 13, smoke: true, ..base() });
    c.push(Scenario { id: "logit_l1_dense_b".into(), datafit: "logistic".into(), n: 120, p: 60, seed: 14, ..base() });
    c.push(Scenario { id: "logit_l1_sparse".into(), datafit: "logistic".into(), n: 200, p: 400, density: 0.05, seed: 15, ..base() });

    // ---- poisson (counts, prox-Newton topology) ----
    c.push(Scenario { id: "poisson_l1_a".into(), datafit: "poisson".into(), seed: 16, smoke: true, ..base() });
    c.push(Scenario { id: "poisson_l1_b".into(), datafit: "poisson".into(), n: 100, p: 50, seed: 17, ..base() });

    // ---- probit (±1 labels, prox-Newton topology) ----
    c.push(Scenario { id: "probit_l1_a".into(), datafit: "probit".into(), seed: 18, smoke: true, ..base() });
    c.push(Scenario { id: "probit_l1_b".into(), datafit: "probit".into(), n: 100, p: 50, seed: 19, ..base() });

    // ---- grouped quadratic: every group penalty ----
    let grp = |id: &str, pen: &str, seed: u64| Scenario {
        id: id.into(),
        datafit: "grouped".into(),
        penalty: pen.into(),
        n: 90,
        p: 60,
        group_size: 5,
        seed,
        ..base()
    };
    c.push(Scenario { smoke: true, ..grp("group_lasso_a", "group_lasso", 20) });
    c.push(Scenario { n: 70, p: 48, group_size: 4, ..grp("group_lasso_b", "group_lasso", 21) });
    c.push(grp("wgroup_lasso_a", "weighted_group_lasso", 22));
    c.push(Scenario { n: 70, p: 48, group_size: 4, ..grp("wgroup_lasso_b", "weighted_group_lasso", 23) });
    c.push(Scenario { smoke: true, ..grp("group_mcp_a", "group_mcp", 24) });
    c.push(grp("group_mcp_b", "group_mcp", 25));
    c.push(Scenario { gamma: 3.7, ..grp("group_scad_a", "group_scad", 26) });
    c.push(Scenario { gamma: 3.7, n: 70, p: 48, group_size: 4, ..grp("group_scad_b", "group_scad", 27) });

    // ---- multitask quadratic (task-major y, p×T coefficient rows) ----
    let mtl = |id: &str, pen: &str, seed: u64| Scenario {
        id: id.into(),
        datafit: "multitask".into(),
        penalty: pen.into(),
        n: 60,
        p: 40,
        n_tasks: 3,
        seed,
        ..base()
    };
    c.push(Scenario { smoke: true, ..mtl("mtl_l21_a", "l21", 28) });
    c.push(Scenario { n_tasks: 4, ..mtl("mtl_l21_b", "l21", 29) });
    c.push(mtl("mtl_mcp_a", "block_mcp", 30));
    c.push(Scenario { n_tasks: 4, ..mtl("mtl_mcp_b", "block_mcp", 31) });

    // ---- batched sibling fusion A/B (ISSUE 9): cells whose specs are
    // batchable, sized to exercise the multi-RHS panel through the
    // scheduler's fusion path ----
    c.push(Scenario { id: "quad_l1_batch_wide".into(), n: 100, p: 240, seed: 32, smoke: true, ..base() });
    c.push(Scenario { id: "quad_mcp_batch_dense".into(), penalty: "mcp".into(), n: 150, p: 100, seed: 33, smoke: true, ..base() });

    // ---- reduced-precision A/B (ISSUE 10): dense quadratic cells whose
    // full-design passes run from the f32 shadow, certified at the
    // precision's floored tolerance ----
    c.push(Scenario { id: "quad_l1_prec_f32".into(), precision: "f32".into(), n: 100, p: 150, seed: 34, smoke: true, ..base() });
    c.push(Scenario { id: "quad_mcp_prec_mixed".into(), penalty: "mcp".into(), precision: "mixed".into(), n: 100, p: 150, seed: 35, smoke: true, ..base() });

    debug_assert!(c.len() >= 30, "corpus shrank below the acceptance floor");
    c
}

/// Load `scenarios.jsonl` when present, else fall back to the built-in
/// corpus. Returns the corpus and a tag naming its source.
pub fn load_corpus(path: Option<&str>) -> Result<(Vec<Scenario>, String)> {
    let path = path.unwrap_or("scenarios.jsonl");
    if Path::new(path).exists() {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let corpus = parse_corpus(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))
            .context("parsing scenario corpus")?;
        Ok((corpus, path.to_string()))
    } else {
        Ok((builtin_corpus(), "builtin".to_string()))
    }
}

// ---------------------------------------------------------------------
// dataset + spec construction
// ---------------------------------------------------------------------

/// Rebuild the spec freshly per variant run (a `Box<dyn FitSpec>` is
/// consumed by job submission).
type SpecFactory = Box<dyn Fn() -> Box<dyn FitSpec>>;

/// Deterministic per-feature ℓ1 weights for weighted-Lasso scenarios:
/// strictly positive and heterogeneous (cycle 0.5 / 1.0 / 1.5).
fn feature_weights(p: usize) -> Vec<f64> {
    (0..p).map(|j| 0.5 + 0.5 * ((j % 3) as f64)).collect()
}

/// Multitask workload: AR(1) design, shared-row-support `W ∈ R^{p×T}`,
/// task-major targets `y[t·n + i] = (X w_t)_i + 0.1 ε` (the
/// [`crate::datafit::multitask::QuadraticMultiTask`] convention).
fn multitask_dataset(n: usize, p: usize, n_tasks: usize, seed: u64) -> Dataset {
    let base = correlated(CorrelatedSpec { n, p, rho: 0.5, nnz: 0, snr: 0.0 }, seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0x5CE1_A210_C04F_084D);
    let active = (p / 8).max(2).min(p);
    let mut w = vec![0.0; p * n_tasks]; // row-major p×T
    for j in 0..active {
        for t in 0..n_tasks {
            w[j * n_tasks + t] = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        }
    }
    let mut y = vec![0.0; n * n_tasks];
    let mut xw = vec![0.0; n];
    for t in 0..n_tasks {
        let wt: Vec<f64> = (0..p).map(|j| w[j * n_tasks + t]).collect();
        base.design.matvec(&wt, &mut xw);
        for i in 0..n {
            y[t * n + i] = xw[i] + 0.1 * rng.normal();
        }
    }
    Dataset {
        name: format!("mtl_{n}x{p}x{n_tasks}_s{seed}"),
        design: base.design,
        y,
        beta_true: Vec::new(),
    }
}

/// Build the scenario's dataset and spec factory. `Err` = the (datafit,
/// penalty) pair is not one this library ships → the runner reports
/// `outcome: "skip"`.
fn build_task(s: &Scenario) -> std::result::Result<(Arc<Dataset>, SpecFactory), String> {
    let dense_spec = CorrelatedSpec {
        n: s.n,
        p: s.p,
        rho: 0.5,
        nnz: (s.p / 10).max(2).min(s.p),
        snr: 5.0,
    };
    let sparse_spec = |binary: bool| SparseSpec {
        n: s.n,
        p: s.p,
        density: s.density,
        support_frac: 0.05,
        snr: 5.0,
        binary,
    };
    let sparse_name = format!("scn_{}", s.id);

    match s.datafit.as_str() {
        "quadratic" => {
            let ds = if s.density < 1.0 {
                sparse(&sparse_name, sparse_spec(false), s.seed)
            } else {
                correlated(dense_spec, s.seed)
            };
            let fac: SpecFactory = match s.penalty.as_str() {
                "l1" => Box::new(|| specs::lasso(1.0)),
                "weighted_l1" => {
                    let p = s.p;
                    Box::new(move || specs::weighted_lasso(1.0, feature_weights(p)))
                }
                "l1l2" => Box::new(|| specs::elastic_net(1.0, 0.7)),
                "mcp" => {
                    let g = s.gamma;
                    Box::new(move || specs::mcp(1.0, g))
                }
                "scad" => {
                    let g = s.gamma;
                    Box::new(move || specs::scad(1.0, g))
                }
                "lq" => {
                    let q = s.q;
                    Box::new(move || specs::lq(1.0, q))
                }
                other => return Err(format!("quadratic × {other:?} is not shipped")),
            };
            Ok((Arc::new(ds), fac))
        }
        "logistic" => {
            if s.penalty != "l1" {
                return Err(format!("logistic × {:?} is not shipped", s.penalty));
            }
            // probit_correlated's ±1 labels serve logistic too; the
            // sparse generator has a native binary mode
            let ds = if s.density < 1.0 {
                sparse(&sparse_name, sparse_spec(true), s.seed)
            } else {
                probit_correlated(dense_spec, s.seed)
            };
            Ok((Arc::new(ds), Box::new(|| specs::logistic_l1(1.0))))
        }
        "poisson" => {
            if s.penalty != "l1" {
                return Err(format!("poisson × {:?} is not shipped", s.penalty));
            }
            let ds = poisson_correlated(
                CorrelatedSpec { snr: 0.0, ..dense_spec },
                s.seed,
            );
            Ok((Arc::new(ds), Box::new(|| specs::poisson_l1(1.0))))
        }
        "probit" => {
            if s.penalty != "l1" {
                return Err(format!("probit × {:?} is not shipped", s.penalty));
            }
            let ds = probit_correlated(dense_spec, s.seed);
            Ok((Arc::new(ds), Box::new(|| specs::probit_l1(1.0))))
        }
        "grouped" => {
            let gs = s.group_size.clamp(1, s.p);
            let n_groups = s.p.div_ceil(gs);
            let (ds, part) = grouped_correlated(
                GroupedSpec {
                    n: s.n,
                    p: s.p,
                    group_size: gs,
                    active_groups: (n_groups / 4).max(1),
                    rho: 0.5,
                    snr: 8.0,
                },
                s.seed,
            );
            let fac: SpecFactory = match s.penalty.as_str() {
                "group_lasso" => {
                    let part = Arc::clone(&part);
                    Box::new(move || specs::group_lasso(1.0, Arc::clone(&part)))
                }
                "weighted_group_lasso" => {
                    let part = Arc::clone(&part);
                    Box::new(move || specs::weighted_group_lasso(1.0, Arc::clone(&part)))
                }
                "group_mcp" => {
                    let (part, g) = (Arc::clone(&part), s.gamma);
                    Box::new(move || specs::group_mcp(1.0, g, Arc::clone(&part)))
                }
                "group_scad" => {
                    let (part, g) = (Arc::clone(&part), s.gamma);
                    Box::new(move || specs::group_scad(1.0, g, Arc::clone(&part)))
                }
                other => return Err(format!("grouped × {other:?} is not shipped")),
            };
            Ok((Arc::new(ds), fac))
        }
        "multitask" => {
            let ds = multitask_dataset(s.n, s.p, s.n_tasks, s.seed);
            let (p, t) = (s.p, s.n_tasks);
            let fac: SpecFactory = match s.penalty.as_str() {
                "l21" => Box::new(move || specs::multitask_l21(1.0, p, t)),
                "block_mcp" => {
                    let g = s.gamma;
                    Box::new(move || specs::multitask_mcp(1.0, g, p, t))
                }
                other => return Err(format!("multitask × {other:?} is not shipped")),
            };
            Ok((Arc::new(ds), fac))
        }
        other => Err(format!("datafit {other:?} is not shipped")),
    }
}

// ---------------------------------------------------------------------
// variant runs
// ---------------------------------------------------------------------

/// One solved path point as the oracles see it.
struct PointRec {
    lambda: f64,
    objective: f64,
    beta: Vec<f64>,
    kkt: f64,
    converged: bool,
    certificate: &'static str,
}

struct PathRun {
    points: Vec<PointRec>,
    total_epochs: usize,
    wall_s: f64,
}

/// Run one warm path sweep on a **fresh** scheduler (no coefficient
/// cache carries over between variants — every variant starts from the
/// same cold state, so engine/thread comparisons are apples-to-apples)
/// under an explicit kernel thread budget.
fn run_path_variant(
    ds: &Arc<Dataset>,
    make_spec: &dyn Fn() -> Box<dyn FitSpec>,
    ratios: &[f64],
    tol: f64,
    engine: InnerEngine,
    threads: usize,
    precision: Precision,
) -> std::result::Result<PathRun, String> {
    set_thread_budget(threads);
    let opts =
        SolverOpts::default().with_tol(tol).with_inner(engine).with_precision(precision);
    let sched = FitScheduler::start(1);
    sched.submit_path(Arc::clone(ds), make_spec(), ratios.to_vec(), opts);
    let drained = drain_one_path(&sched, ratios.len());
    sched.shutdown();
    drained
}

/// Run the batched A/B variant: two identical sibling paths submitted at
/// batch priority behind a blocker fit, so the lead finds its sibling
/// still queued and fuses it into one multi-RHS panel job (ISSUE 9).
/// Returns both member runs plus whether fusion actually fired (the lone
/// worker may, rarely, drain the queue before the sibling lands — the
/// correctness oracle holds either way, so fusion is reported, not
/// required).
fn run_batched_variant(
    ds: &Arc<Dataset>,
    make_spec: &dyn Fn() -> Box<dyn FitSpec>,
    ratios: &[f64],
    tol: f64,
    precision: Precision,
) -> std::result::Result<(Vec<PathRun>, bool), String> {
    set_thread_budget(1);
    let opts = SolverOpts::default().with_tol(tol).with_precision(precision);
    let sched = FitScheduler::start(1);
    let blocker = sched.submit_fit(Arc::clone(ds), make_spec(), opts.clone());
    let lead = sched.submit_path(Arc::clone(ds), make_spec(), ratios.to_vec(), opts.clone());
    let sib = sched.submit_path(Arc::clone(ds), make_spec(), ratios.to_vec(), opts);
    let mut recs: std::collections::HashMap<u64, Vec<(usize, PointRec)>> =
        [(lead, Vec::new()), (sib, Vec::new())].into_iter().collect();
    let mut done: std::collections::HashMap<u64, (usize, f64)> =
        std::collections::HashMap::new();
    let mut blocker_done = false;
    while !(blocker_done && done.len() == 2) {
        match sched.events.recv() {
            Ok(JobEvent::FitDone(f)) if f.job_id == blocker => blocker_done = true,
            Ok(JobEvent::PathPoint(p)) => {
                recs.entry(p.job_id).or_default().push((
                    p.index,
                    PointRec {
                        lambda: p.point.lambda,
                        objective: p.point.objective,
                        beta: p.point.beta,
                        kkt: p.kkt,
                        converged: p.converged,
                        certificate: p.certificate.name(),
                    },
                ));
            }
            Ok(JobEvent::PathDone(s)) => {
                done.insert(s.job_id, (s.total_epochs, s.total_time));
            }
            Ok(JobEvent::Failed { job_id, message }) => {
                return Err(format!("job {job_id} panicked on its worker: {message}"))
            }
            Ok(JobEvent::Cancelled { job_id, .. }) => {
                return Err(format!("job {job_id} was cancelled"))
            }
            Ok(JobEvent::FitDone(f)) => {
                return Err(format!("unexpected FitDone for job {}", f.job_id))
            }
            Ok(JobEvent::SchedulerDown) | Err(_) => return Err("scheduler died".into()),
        }
    }
    let fused = sched.fusion_stats().batched_jobs > 0;
    sched.shutdown();
    let mut runs = Vec::with_capacity(2);
    for id in [lead, sib] {
        let mut points = recs.remove(&id).unwrap_or_default();
        points.sort_by_key(|(i, _)| *i);
        if points.len() != ratios.len() {
            return Err(format!(
                "sibling path {id} emitted {} points, expected {}",
                points.len(),
                ratios.len()
            ));
        }
        let (total_epochs, wall_s) = done[&id];
        runs.push(PathRun {
            points: points.into_iter().map(|(_, r)| r).collect(),
            total_epochs,
            wall_s,
        });
    }
    Ok((runs, fused))
}

fn drain_one_path(
    sched: &FitScheduler,
    n_points: usize,
) -> std::result::Result<PathRun, String> {
    let mut recs: Vec<(usize, PointRec)> = Vec::with_capacity(n_points);
    loop {
        match sched.events.recv() {
            Ok(JobEvent::PathPoint(p)) => {
                recs.push((
                    p.index,
                    PointRec {
                        lambda: p.point.lambda,
                        objective: p.point.objective,
                        beta: p.point.beta,
                        kkt: p.kkt,
                        converged: p.converged,
                        certificate: p.certificate.name(),
                    },
                ));
            }
            Ok(JobEvent::PathDone(s)) => {
                recs.sort_by_key(|(i, _)| *i);
                if recs.len() != n_points {
                    return Err(format!(
                        "path emitted {} points, expected {n_points}",
                        recs.len()
                    ));
                }
                return Ok(PathRun {
                    points: recs.into_iter().map(|(_, r)| r).collect(),
                    total_epochs: s.total_epochs,
                    wall_s: s.total_time,
                });
            }
            Ok(JobEvent::Failed { message, .. }) => {
                return Err(format!("solve panicked on its worker: {message}"))
            }
            Ok(JobEvent::FitDone(_)) => return Err("unexpected FitDone event".into()),
            Ok(JobEvent::Cancelled { .. }) => return Err("path job was cancelled".into()),
            Ok(JobEvent::SchedulerDown) | Err(_) => return Err("scheduler died".into()),
        }
    }
}

// ---------------------------------------------------------------------
// oracles + per-scenario driver
// ---------------------------------------------------------------------

/// Cross-engine agreement bar for convex scenarios (ISSUE-mandated).
const ENGINE_TOL: f64 = 1e-10;
/// Cross-engine objective bar for non-convex scenarios (identical update
/// order makes engines track each other to rounding; a different
/// critical point would blow far past this).
const ENGINE_TOL_NONCONVEX: f64 = 1e-6;

/// The AgentLab-style structured result of one scenario.
pub struct ScenarioOutcome {
    pub scenario_id: String,
    /// "pass" | "fail" | "skip"
    pub outcome: &'static str,
    /// baseline objective at the smallest λ (NaN when skipped)
    pub objective: f64,
    pub metrics: Json,
    pub violations: Vec<String>,
}

impl ScenarioOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("scenario_id", self.scenario_id.as_str())
            .with("outcome", self.outcome)
            .with("objective", self.objective)
            .with("metrics", self.metrics.clone())
            .with(
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            )
    }
}

fn rel_dev(a: f64, b: f64) -> f64 {
    (a - b).abs() / (1.0 + a.abs())
}

/// Max relative deviation between two runs over (objective, every
/// coefficient), λ-by-λ.
fn max_run_dev(a: &PathRun, b: &PathRun) -> f64 {
    let mut worst = 0.0f64;
    for (pa, pb) in a.points.iter().zip(b.points.iter()) {
        worst = worst.max(rel_dev(pa.objective, pb.objective));
        for (&x, &y) in pa.beta.iter().zip(pb.beta.iter()) {
            worst = worst.max(rel_dev(x, y));
        }
    }
    worst
}

fn runs_bit_identical(a: &PathRun, b: &PathRun) -> bool {
    a.points.len() == b.points.len()
        && a.points.iter().zip(b.points.iter()).all(|(pa, pb)| {
            pa.objective.to_bits() == pb.objective.to_bits()
                && pa.beta.len() == pb.beta.len()
                && pa
                    .beta
                    .iter()
                    .zip(pb.beta.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        })
}

/// Run one scenario's full variant plan and check its oracles.
pub fn run_scenario(s: &Scenario) -> ScenarioOutcome {
    let (ds, make_spec) = match build_task(s) {
        Ok(t) => t,
        Err(reason) => {
            return ScenarioOutcome {
                scenario_id: s.id.clone(),
                outcome: "skip",
                objective: f64::NAN,
                metrics: Json::obj().with("reason", reason),
                violations: Vec::new(),
            }
        }
    };
    let convex = make_spec().is_convex();
    // declared precision + the floored certificate bar: a reduced-
    // precision solve cannot certify below Precision::tol_floor, so
    // every kkt oracle in this scenario uses the floored tolerance
    let prec = Precision::parse(&s.precision).unwrap_or_default();
    let ftol = s.tol.max(prec.tol_floor());
    // 3-λ geometric-ish grid from 0.5·λ_max down to the declared ratio
    let ratios = vec![0.5, (0.5 * s.lambda_ratio).sqrt(), s.lambda_ratio];
    let mut violations: Vec<String> = Vec::new();

    // ---- baseline: residual engine, 1 thread, warm sweep ----
    let baseline = match run_path_variant(
        &ds,
        &make_spec,
        &ratios,
        s.tol,
        InnerEngine::Residual,
        1,
        prec,
    ) {
        Ok(r) => r,
        Err(e) => {
            return ScenarioOutcome {
                scenario_id: s.id.clone(),
                outcome: "fail",
                objective: f64::NAN,
                metrics: Json::obj(),
                violations: vec![format!("baseline run failed: {e}")],
            }
        }
    };
    for (i, pt) in baseline.points.iter().enumerate() {
        if !pt.objective.is_finite() {
            violations.push(format!("point {i}: non-finite objective {}", pt.objective));
        }
        if !(pt.kkt <= ftol) {
            violations.push(format!(
                "point {i} (λ={:.3e}): {} {:.3e} exceeds floored tol {:.1e}",
                pt.lambda, pt.certificate, pt.kkt, ftol
            ));
        }
        if !pt.converged {
            violations.push(format!("point {i}: solver reports converged = false"));
        }
    }

    // ---- warm == cold, λ-by-λ (convex scenarios: any start reaches the
    // same optimum; non-convex fits may legitimately land on different
    // critical points, so the oracle is convex-gated) ----
    let mut warm_cold_dev: Option<f64> = None;
    if convex {
        let bar = (100.0 * ftol).max(1e-9);
        let mut worst = 0.0f64;
        for (i, &r) in ratios.iter().enumerate() {
            match run_path_variant(&ds, &make_spec, &[r], s.tol, InnerEngine::Residual, 1, prec) {
                Ok(cold) => {
                    let dev = rel_dev(baseline.points[i].objective, cold.points[0].objective);
                    worst = worst.max(dev);
                    if !(dev <= bar) {
                        violations.push(format!(
                            "warm≠cold at λ-point {i}: objectives {:.12e} vs {:.12e} (rel dev {dev:.3e} > {bar:.1e})",
                            baseline.points[i].objective, cold.points[0].objective
                        ));
                    }
                }
                Err(e) => violations.push(format!("cold run at λ-point {i} failed: {e}")),
            }
        }
        warm_cold_dev = Some(worst);
    }

    // ---- cross-engine agreement (Gram contract: quadratic datafit) ----
    let mut engine_dev: Option<f64> = None;
    if s.datafit == "quadratic" {
        // reduced precision quantises both engines' gradients at the
        // storage epsilon, so the strict f64 agreement bars don't apply;
        // the floored-certificate-scale bar does
        let bar = if prec != Precision::F64 {
            (100.0 * ftol).max(1e-9)
        } else if convex {
            ENGINE_TOL
        } else {
            ENGINE_TOL_NONCONVEX
        };
        let mut worst = 0.0f64;
        for engine in [InnerEngine::Gram, InnerEngine::Auto] {
            match run_path_variant(&ds, &make_spec, &ratios, s.tol, engine, 1, prec) {
                Ok(run) => {
                    for (i, pt) in run.points.iter().enumerate() {
                        if !(pt.kkt <= ftol) {
                            violations.push(format!(
                                "{engine:?} engine point {i}: {} {:.3e} exceeds floored tol {:.1e}",
                                pt.certificate, pt.kkt, ftol
                            ));
                        }
                    }
                    let dev = if convex {
                        max_run_dev(&baseline, &run)
                    } else {
                        // objective-only for non-convex (see ENGINE_TOL_NONCONVEX)
                        baseline
                            .points
                            .iter()
                            .zip(run.points.iter())
                            .map(|(a, b)| rel_dev(a.objective, b.objective))
                            .fold(0.0, f64::max)
                    };
                    worst = worst.max(dev);
                    if !(dev <= bar) {
                        violations.push(format!(
                            "{engine:?} engine deviates from residual: max rel dev {dev:.3e} > {bar:.1e}"
                        ));
                    }
                }
                Err(e) => violations.push(format!("{engine:?} engine run failed: {e}")),
            }
        }
        engine_dev = Some(worst);
    }

    // ---- thread-count bit-invariance (residual engine; the Auto
    // dispatcher's cost model is timing-fed, so only the explicit engine
    // promises bitwise reproducibility) ----
    let mut thread_bit_identical: Option<bool> = None;
    match run_path_variant(&ds, &make_spec, &ratios, s.tol, InnerEngine::Residual, 4, prec) {
        Ok(t4) => {
            let same = runs_bit_identical(&baseline, &t4);
            thread_bit_identical = Some(same);
            if !same {
                violations.push(
                    "thread budget 4 changed results bitwise vs budget 1".to_string(),
                );
            }
        }
        Err(e) => violations.push(format!("4-thread run failed: {e}")),
    }

    // ---- batched sibling fusion (ISSUE 9): two identical sibling paths
    // fuse into one multi-RHS panel job; every member must land on the
    // baseline objectives λ-by-λ. Fused members skip the gap-safe pass
    // (the panel amortises it), so the bar is objective agreement at the
    // warm/cold tolerance, not bitwise identity with the screened run ----
    let mut batch_dev: Option<f64> = None;
    let mut batch_fused: Option<bool> = None;
    if crate::solver::batching_enabled() && make_spec().batch_penalty().is_some() {
        let bar = if convex {
            (100.0 * ftol).max(1e-9)
        } else {
            ENGINE_TOL_NONCONVEX.max(100.0 * ftol)
        };
        match run_batched_variant(&ds, &make_spec, &ratios, s.tol, prec) {
            Ok((runs, fused)) => {
                let mut worst = 0.0f64;
                for (m, run) in runs.iter().enumerate() {
                    let dev = baseline
                        .points
                        .iter()
                        .zip(run.points.iter())
                        .map(|(a, b)| rel_dev(a.objective, b.objective))
                        .fold(0.0, f64::max);
                    worst = worst.max(dev);
                    if !(dev <= bar) {
                        violations.push(format!(
                            "batched sibling {m} deviates from baseline: max objective rel dev {dev:.3e} > {bar:.1e}"
                        ));
                    }
                }
                batch_dev = Some(worst);
                batch_fused = Some(fused);
            }
            Err(e) => violations.push(format!("batched sibling run failed: {e}")),
        }
    }

    // ---- f64 reference A/B (ISSUE 10): a reduced-precision scenario
    // also solves the same warm sweep in full f64. The objective
    // deviation is *recorded*, never gated — the floored certificate
    // above is the contract; closeness to f64 is diagnostic ----
    let mut precision_ref_dev: Option<f64> = None;
    if prec != Precision::F64 {
        match run_path_variant(
            &ds,
            &make_spec,
            &ratios,
            s.tol,
            InnerEngine::Residual,
            1,
            Precision::F64,
        ) {
            Ok(reference) => {
                let dev = baseline
                    .points
                    .iter()
                    .zip(reference.points.iter())
                    .map(|(a, b)| rel_dev(a.objective, b.objective))
                    .fold(0.0, f64::max);
                precision_ref_dev = Some(dev);
            }
            Err(e) => violations.push(format!("f64 reference run failed: {e}")),
        }
    }

    let final_pt = baseline.points.last().expect("baseline has points");
    let mut metrics = Json::obj()
        .with("datafit", s.datafit.as_str())
        .with("penalty", s.penalty.as_str())
        .with("convex", convex)
        .with("tol", s.tol)
        .with("precision", s.precision.as_str())
        .with("floored_tol", ftol)
        .with("certificate", final_pt.certificate)
        .with("kkt_final", final_pt.kkt)
        .with("n_points", baseline.points.len())
        .with("total_epochs", baseline.total_epochs)
        .with("wall_s", baseline.wall_s);
    metrics = match engine_dev {
        Some(d) => metrics.with("engine_max_dev", d),
        None => metrics.with("engine_max_dev", Json::Null),
    };
    metrics = match thread_bit_identical {
        Some(b) => metrics.with("thread_bit_identical", b),
        None => metrics.with("thread_bit_identical", Json::Null),
    };
    metrics = match warm_cold_dev {
        Some(d) => metrics.with("warm_cold_max_dev", d),
        None => metrics.with("warm_cold_max_dev", Json::Null),
    };
    metrics = match batch_dev {
        Some(d) => metrics.with("batch_max_dev", d),
        None => metrics.with("batch_max_dev", Json::Null),
    };
    metrics = match batch_fused {
        Some(b) => metrics.with("batch_fused", b),
        None => metrics.with("batch_fused", Json::Null),
    };
    metrics = match precision_ref_dev {
        Some(d) => metrics.with("precision_ref_dev", d),
        None => metrics.with("precision_ref_dev", Json::Null),
    };

    ScenarioOutcome {
        scenario_id: s.id.clone(),
        outcome: if violations.is_empty() { "pass" } else { "fail" },
        objective: final_pt.objective,
        metrics,
        violations,
    }
}

// ---------------------------------------------------------------------
// corpus driver + result emission
// ---------------------------------------------------------------------

pub struct ConformReport {
    pub outcomes: Vec<ScenarioOutcome>,
    pub source: String,
}

impl ConformReport {
    pub fn count(&self, outcome: &str) -> usize {
        self.outcomes.iter().filter(|o| o.outcome == outcome).count()
    }
}

/// Run the corpus (optionally filtered to ids/datafits/penalties
/// containing `filter`, and/or to the smoke subset). Restores the
/// caller's kernel thread budget afterwards — variant runs mutate the
/// global budget.
pub fn run_corpus(
    corpus: &[Scenario],
    filter: Option<&str>,
    smoke_only: bool,
    source: &str,
) -> Result<ConformReport> {
    let selected: Vec<&Scenario> = corpus
        .iter()
        .filter(|s| !smoke_only || s.smoke)
        .filter(|s| {
            filter
                .map(|f| s.id.contains(f) || s.datafit.contains(f) || s.penalty.contains(f))
                .unwrap_or(true)
        })
        .collect();
    if selected.is_empty() {
        anyhow::bail!(
            "no scenarios selected from {source} (filter {filter:?}, smoke_only {smoke_only})"
        );
    }
    let saved_budget = thread_budget();
    let mut outcomes = Vec::with_capacity(selected.len());
    for s in selected {
        let o = run_scenario(s);
        let wall = o.metrics.get("wall_s").and_then(|j| j.as_f64()).unwrap_or(0.0);
        eprintln!("[conform] {:<22} {:<4} ({wall:.2}s)", o.scenario_id, o.outcome);
        for v in &o.violations {
            eprintln!("[conform]   violation: {v}");
        }
        outcomes.push(o);
    }
    set_thread_budget(saved_budget);
    Ok(ConformReport { outcomes, source: source.to_string() })
}

/// Emit per-scenario JSON files + the `BENCH_scenarios.json` aggregate
/// (results dir always; repo root only outside `SKGLM_RESULTS`
/// redirection, the shared BENCH convention).
pub fn write_report(report: &ConformReport) -> Result<Vec<PathBuf>> {
    let dir = results_dir().join("scenarios");
    ensure_dir(&dir)?;
    let mut written = Vec::new();
    for o in &report.outcomes {
        let path = dir.join(format!("{}.json", o.scenario_id));
        std::fs::write(&path, o.to_json().render())
            .with_context(|| format!("writing {}", path.display()))?;
        written.push(path);
    }
    let agg = Json::obj()
        .with("experiment", "scenarios")
        .with("source", report.source.as_str())
        .with("total", report.outcomes.len())
        .with("pass", report.count("pass"))
        .with("fail", report.count("fail"))
        .with("skip", report.count("skip"))
        .with(
            "scenarios",
            Json::Arr(report.outcomes.iter().map(|o| o.to_json()).collect()),
        );
    let agg_path = dir.join("BENCH_scenarios.json");
    std::fs::write(&agg_path, agg.render())
        .with_context(|| format!("writing {}", agg_path.display()))?;
    written.push(agg_path);
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_scenarios.json");
        std::fs::write(&root, agg.render())
            .with_context(|| format!("writing {}", root.display()))?;
        written.push(root);
    }
    Ok(written)
}

/// The `skglm conform` / `exp scenarios` entry point: load → run → emit →
/// **fail** (a real error, so the CI gate trips) when any scenario fails
/// its oracles.
pub fn conform(corpus_path: Option<&str>, filter: Option<&str>, smoke_only: bool) -> Result<Vec<PathBuf>> {
    let (corpus, source) = load_corpus(corpus_path)?;
    let report = run_corpus(&corpus, filter, smoke_only, &source)?;
    let written = write_report(&report)?;
    let (pass, fail, skip) =
        (report.count("pass"), report.count("fail"), report.count("skip"));
    eprintln!(
        "[conform] {} scenarios from {}: {pass} pass / {fail} fail / {skip} skip",
        report.outcomes.len(),
        report.source
    );
    if fail > 0 {
        anyhow::bail!("{fail} scenario(s) failed their conformance oracles");
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no solver runs here — [`run_scenario`] mutates the global
    // kernel thread budget, which must not race the other unit tests in
    // this binary. The end-to-end conform run lives in
    // tests/integration_scenarios.rs (its own process).

    #[test]
    fn builtin_corpus_meets_the_acceptance_floor() {
        let c = builtin_corpus();
        assert!(c.len() >= 30, "corpus has only {} scenarios", c.len());
        // unique ids
        let mut ids: Vec<&str> = c.iter().map(|s| s.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), c.len(), "duplicate scenario ids");
        // every shipped datafit appears
        for df in ["quadratic", "logistic", "poisson", "probit", "grouped", "multitask"] {
            assert!(c.iter().any(|s| s.datafit == df), "no {df} scenario");
        }
        // every shipped penalty family appears
        for pen in [
            "l1", "weighted_l1", "l1l2", "mcp", "scad", "lq", "group_lasso",
            "weighted_group_lasso", "group_mcp", "group_scad", "l21", "block_mcp",
        ] {
            assert!(c.iter().any(|s| s.penalty == pen), "no {pen} scenario");
        }
        // the smoke subset covers every datafit (the CI gate's floor)
        for df in ["quadratic", "logistic", "poisson", "probit", "grouped", "multitask"] {
            assert!(c.iter().any(|s| s.smoke && s.datafit == df), "no smoke {df} scenario");
        }
        // both densities appear
        assert!(c.iter().any(|s| s.density < 1.0));
        // both reduced precisions are smoke-gated (ISSUE 10)
        for pr in ["f32", "mixed"] {
            assert!(
                c.iter().any(|s| s.smoke && s.precision == pr),
                "no smoke precision={pr} scenario"
            );
        }
        // every scenario's (datafit, penalty) pair actually builds
        for s in &c {
            assert!(build_task(s).is_ok(), "{}: task does not build", s.id);
        }
    }

    #[test]
    fn corpus_round_trips_through_jsonl() {
        let c = builtin_corpus();
        let text = render_corpus(&c);
        let parsed = parse_corpus(&text).unwrap();
        assert_eq!(parsed, c);
    }

    #[test]
    fn parse_rejects_bad_corpus_lines() {
        assert!(parse_corpus("not json\n").is_err());
        assert!(parse_corpus("[1,2]\n").is_err(), "non-object line must fail");
        assert!(parse_corpus("{\"datafit\":\"quadratic\"}\n").is_err(), "missing id");
        assert!(
            parse_corpus("{\"id\":\"x\",\"frobnicate\":1}\n").is_err(),
            "unknown field must fail loudly"
        );
        assert!(
            parse_corpus("{\"id\":\"a\"}\n{\"id\":\"a\"}\n").is_err(),
            "duplicate ids must fail"
        );
        assert!(
            parse_corpus("{\"id\":\"a\",\"lambda_ratio\":0.9}\n").is_err(),
            "ratio above the warm anchor must fail"
        );
        assert!(
            parse_corpus("{\"id\":\"a\",\"precision\":\"f16\"}\n").is_err(),
            "unknown precision must fail loudly"
        );
    }

    #[test]
    fn defaults_fill_missing_fields_and_blank_lines_skip() {
        let c = parse_corpus("\n{\"id\":\"tiny\"}\n\n").unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c[0], Scenario { id: "tiny".into(), ..Scenario::default() });
    }

    #[test]
    fn unshipped_pairs_are_skips_not_failures() {
        let s = Scenario {
            id: "future".into(),
            datafit: "cox".into(),
            ..Scenario::default()
        };
        assert!(build_task(&s).is_err());
        let o = run_scenario(&s);
        assert_eq!(o.outcome, "skip");
        assert!(o.violations.is_empty());
        assert!(o.objective.is_nan());
    }
}
