//! Bench scenario `kernels`: the kernel engine measured serial vs blocked
//! vs parallel across n×p / density / thread-count grids.
//!
//! Variants per workload:
//! - `serial`  — the naive per-column reference (`DenseMatrix::matvec_t` /
//!   `CscMatrix::matvec_t`), what every pass ran before ISSUE 2;
//! - `blocked` — the panel/balanced kernel on one thread
//!   (`Design::matvec_t_threads(.., 1)`): the pure cache-blocking win;
//! - `parallel-T` — the same kernel on T threads;
//! - `policy`  — `Design::matvec_t` as the solver calls it: the global
//!   [`crate::linalg::KernelPolicy`] picks the thread count, falling back
//!   to serial below the work threshold (what "no regression at smoke
//!   scale" means — tiny passes must not pay dispatch overhead).
//!
//! Results land in `results/kernels/` and — the perf-trajectory anchor —
//! `BENCH_kernels.json` at the repo root (skipped when `SKGLM_RESULTS`
//! redirects outputs, e.g. under `cargo test`).

use crate::bench::figures::Scale;
use crate::bench::report::{ensure_dir, results_dir, write_markdown};
use crate::data::{correlated, sparse, CorrelatedSpec, SparseSpec};
use crate::linalg::parallel::{thread_budget, KernelPolicy, SERIAL_WORK_THRESHOLD};
use crate::linalg::Design;
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;
use std::hint::black_box;
use std::path::PathBuf;
use std::time::Instant;

/// One timed kernel invocation.
#[derive(Clone, Debug)]
pub struct KernelBenchRow {
    /// kernel family: `xtr_dense`, `xtr_sparse`, `col_sq_norms_dense`
    pub kernel: String,
    /// workload shape, e.g. `1000x2000` or `5000x50000@1e-3`
    pub shape: String,
    /// `serial` | `blocked` | `parallel-T` | `policy`
    pub variant: String,
    /// threads actually used
    pub threads: usize,
    /// median wall time
    pub micros: f64,
    /// stored entries touched per second, in millions
    pub mitems_per_s: f64,
    /// serial median time / this variant's median time
    pub speedup_vs_serial: f64,
}

/// median-of-`reps` wall time of `f`, after `warmup` runs. Shared with
/// `benches/micro_kernels.rs` so all §Perf numbers use one timing rule.
pub fn time_it<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2]
}

/// Thread counts to sweep: powers of two up to the budget, plus the
/// budget itself.
fn thread_grid() -> Vec<usize> {
    let budget = thread_budget();
    let mut grid = Vec::new();
    let mut t = 2usize;
    while t < budget {
        grid.push(t);
        t *= 2;
    }
    if budget >= 2 {
        grid.push(budget);
    }
    grid.dedup();
    grid
}

/// Benchmark one design's `Xᵀr` under every variant.
fn bench_xtr(
    kernel: &str,
    shape: &str,
    design: &Design,
    warmup: usize,
    reps: usize,
    rows: &mut Vec<KernelBenchRow>,
) {
    let n = design.nrows();
    let p = design.ncols();
    let work = design.stored_entries() as f64;
    let r: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).cos()).collect();
    let mut out = vec![0.0; p];

    let serial_secs = time_it(warmup, reps, || {
        match design {
            Design::Dense(m) => m.matvec_t(&r, &mut out),
            Design::Sparse(m) => m.matvec_t(&r, &mut out),
        }
        black_box(&out);
    });
    let mut push = |variant: String, threads: usize, secs: f64| {
        rows.push(KernelBenchRow {
            kernel: kernel.to_string(),
            shape: shape.to_string(),
            variant,
            threads,
            micros: secs * 1e6,
            mitems_per_s: work / secs / 1e6,
            speedup_vs_serial: serial_secs / secs,
        });
    };
    push("serial".to_string(), 1, serial_secs);

    let blocked_secs = time_it(warmup, reps, || {
        design.matvec_t_threads(&r, &mut out, 1);
        black_box(&out);
    });
    push("blocked".to_string(), 1, blocked_secs);

    for t in thread_grid() {
        let secs = time_it(warmup, reps, || {
            design.matvec_t_threads(&r, &mut out, t);
            black_box(&out);
        });
        push(format!("parallel-{t}"), t, secs);
    }

    let policy_threads = KernelPolicy::global().threads_for(design.stored_entries());
    let policy_secs = time_it(warmup, reps, || {
        design.matvec_t(&r, &mut out);
        black_box(&out);
    });
    push("policy".to_string(), policy_threads, policy_secs);
}

/// Benchmark `col_sq_norms` (Gram-diagonal precompute) on one design.
fn bench_col_norms(
    shape: &str,
    design: &Design,
    warmup: usize,
    reps: usize,
    rows: &mut Vec<KernelBenchRow>,
) {
    let p = design.ncols();
    let work = design.stored_entries() as f64;
    let mut out = vec![0.0; p];
    let serial_secs = time_it(warmup, reps, || {
        design.col_sq_norms_threads(&mut out, 1);
        black_box(&out);
    });
    rows.push(KernelBenchRow {
        kernel: "col_sq_norms_dense".to_string(),
        shape: shape.to_string(),
        variant: "serial".to_string(),
        threads: 1,
        micros: serial_secs * 1e6,
        mitems_per_s: work / serial_secs / 1e6,
        speedup_vs_serial: 1.0,
    });
    for t in thread_grid() {
        let secs = time_it(warmup, reps, || {
            design.col_sq_norms_threads(&mut out, t);
            black_box(&out);
        });
        rows.push(KernelBenchRow {
            kernel: "col_sq_norms_dense".to_string(),
            shape: shape.to_string(),
            variant: format!("parallel-{t}"),
            threads: t,
            micros: secs * 1e6,
            mitems_per_s: work / secs / 1e6,
            speedup_vs_serial: serial_secs / secs,
        });
    }
}

/// Run the kernel-engine grid and persist `BENCH_kernels.json`.
pub fn run_kernels(scale: Scale) -> Result<Vec<PathBuf>> {
    let (dense_shapes, sparse_shapes, warmup, reps): (
        Vec<(usize, usize)>,
        Vec<(usize, usize, f64)>,
        usize,
        usize,
    ) = match scale {
        // smoke: below the serial threshold so the policy fallback engages
        Scale::Smoke => (vec![(100, 200)], vec![(1000, 4000, 1e-3)], 2, 5),
        // full: fig1 scale (1000×2000) + a larger panel-bound shape,
        // sparse at two densities
        Scale::Full => (
            vec![(1000, 2000), (2000, 4000)],
            vec![(5000, 50_000, 1e-3), (5000, 50_000, 1e-2)],
            3,
            9,
        ),
    };

    let mut rows: Vec<KernelBenchRow> = Vec::new();
    for &(n, p) in &dense_shapes {
        let ds = correlated(
            CorrelatedSpec { n, p, rho: 0.5, nnz: (p / 20).max(1), snr: 8.0 },
            42,
        );
        let shape = format!("{n}x{p}");
        bench_xtr("xtr_dense", &shape, &ds.design, warmup, reps, &mut rows);
        if (n, p) == dense_shapes[0] {
            bench_col_norms(&shape, &ds.design, warmup, reps, &mut rows);
        }
    }
    for &(n, p, density) in &sparse_shapes {
        let ds = sparse(
            "kernels",
            SparseSpec { n, p, density, support_frac: 0.001, snr: 5.0, binary: false },
            7,
        );
        let shape = format!("{n}x{p}@{density:e}");
        bench_xtr("xtr_sparse", &shape, &ds.design, warmup, reps, &mut rows);
    }

    // ---- report ----
    let mut t = Table::new(&[
        "kernel", "shape", "variant", "threads", "median_us", "Mitem_per_s", "speedup_vs_serial",
    ]);
    for r in &rows {
        t.row(vec![
            r.kernel.clone(),
            r.shape.clone(),
            r.variant.clone(),
            r.threads.to_string(),
            format!("{:.1}", r.micros),
            format!("{:.1}", r.mitems_per_s),
            format!("{:.2}x", r.speedup_vs_serial),
        ]);
    }
    let md = write_markdown("kernels", "kernel_engine", &t)?;

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .with("kernel", r.kernel.as_str())
                .with("shape", r.shape.as_str())
                .with("variant", r.variant.as_str())
                .with("threads", r.threads)
                .with("median_us", r.micros)
                .with("mitems_per_s", r.mitems_per_s)
                .with("speedup_vs_serial", r.speedup_vs_serial)
        })
        .collect();
    let json = Json::obj()
        .with("bench", "kernels")
        .with(
            "scale",
            match scale {
                Scale::Smoke => "smoke",
                Scale::Full => "full",
            },
        )
        .with("thread_budget", thread_budget())
        .with("serial_work_threshold", SERIAL_WORK_THRESHOLD)
        .with("rows", Json::Arr(jrows));

    let dir = results_dir().join("kernels");
    ensure_dir(&dir)?;
    let json_path = dir.join("BENCH_kernels.json");
    std::fs::write(&json_path, json.render())?;
    let mut outputs = vec![json_path, md];
    // the repo-root trajectory file (skipped when results are redirected,
    // e.g. by tests)
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_kernels.json");
        std::fs::write(&root, json.render())?;
        outputs.push(root);
    }

    // headline: best parallel speedup of the dense scoring pass
    if let Some(best) = rows
        .iter()
        .filter(|r| r.kernel == "xtr_dense" && r.variant.starts_with("parallel"))
        .max_by(|a, b| a.speedup_vs_serial.partial_cmp(&b.speedup_vs_serial).unwrap())
    {
        eprintln!(
            "[kernels] dense scoring pass {}: {} = {:.2}x over serial ({} threads, budget {})",
            best.shape,
            best.variant,
            best.speedup_vs_serial,
            best.threads,
            thread_budget()
        );
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_runs_and_persists_json() {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_kernels_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let out = run_kernels(Scale::Smoke).unwrap();
        assert!(!out.is_empty());
        for p in &out {
            assert!(p.exists(), "{}", p.display());
        }
        let raw = std::fs::read_to_string(&out[0]).unwrap();
        assert!(raw.contains("\"bench\":\"kernels\""));
        assert!(raw.contains("xtr_dense"));
        assert!(raw.contains("xtr_sparse"));
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
