//! benchopt-style black-box benchmarking (Moreau et al. 2022) — the
//! paper's §3 methodology: each solver is re-run from scratch with an
//! increasing iteration budget; every run records (budget, wall time,
//! objective, metric). Curves are non-monotone in time by construction
//! (Figure 10), which [`SolverCurve::monotone_envelope`] optionally cleans
//! for reporting.

use crate::util::json::Json;

/// One (budget → outcome) sample.
#[derive(Clone, Debug)]
pub struct BenchPoint {
    pub budget: usize,
    /// wall-clock seconds of this run
    pub time: f64,
    pub objective: f64,
    /// duality gap / stationarity / suboptimality — figure-dependent
    pub metric: f64,
}

/// A solver's convergence curve on one problem.
#[derive(Clone, Debug)]
pub struct SolverCurve {
    pub solver: String,
    pub points: Vec<BenchPoint>,
}

impl SolverCurve {
    /// Earliest time at which the curve reaches `target` (metric at or
    /// below); `None` if never reached. Points with a non-finite time or
    /// metric are ignored — a timed-out or diverged rerun (`NaN`/`inf`)
    /// must not report an (unreachable) finite time-to-target.
    pub fn time_to_target(&self, target: f64) -> Option<f64> {
        self.points
            .iter()
            .filter(|p| p.time.is_finite() && p.metric.is_finite() && p.metric <= target)
            .map(|p| p.time)
            .fold(None, |acc, t| Some(acc.map_or(t, |a: f64| a.min(t))))
    }

    /// Alias kept for the figure runners; see [`SolverCurve::time_to_target`].
    pub fn time_to(&self, target: f64) -> Option<f64> {
        self.time_to_target(target)
    }

    /// Best metric achieved within a time budget.
    pub fn best_within(&self, time_budget: f64) -> Option<f64> {
        self.points
            .iter()
            .filter(|p| p.time <= time_budget)
            .map(|p| p.metric)
            .fold(None, |acc, m| Some(acc.map_or(m, |a: f64| a.min(m))))
    }

    /// Sorted-by-time, cumulative-min metric (cleaned curve for tables).
    /// Non-finite samples are dropped up front: a `NaN` time used to
    /// panic the sort, and a `NaN`/`inf` metric would poison every later
    /// envelope value. Empty in → empty out.
    pub fn monotone_envelope(&self) -> Vec<(f64, f64)> {
        let mut pts: Vec<(f64, f64)> = self
            .points
            .iter()
            .filter(|p| p.time.is_finite() && p.metric.is_finite())
            .map(|p| (p.time, p.metric))
            .collect();
        pts.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut best = f64::INFINITY;
        pts.iter()
            .map(|&(t, m)| {
                best = best.min(m);
                (t, best)
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj().with("solver", self.solver.as_str()).with(
            "points",
            Json::Arr(
                self.points
                    .iter()
                    .map(|p| {
                        Json::obj()
                            .with("budget", p.budget)
                            .with("time", p.time)
                            .with("objective", p.objective)
                            .with("metric", p.metric)
                    })
                    .collect(),
            ),
        )
    }
}

/// Geometric budget schedule 1, 2, 3, 5, 8, 13, … up to `max` (benchopt's
/// default growth), always ending exactly at `max`.
pub fn budget_schedule(max: usize, growth: f64) -> Vec<usize> {
    assert!(growth > 1.0);
    let mut out = Vec::new();
    let mut b = 1.0f64;
    loop {
        let v = b.round() as usize;
        if out.last() != Some(&v) {
            out.push(v);
        }
        if v >= max {
            break;
        }
        b *= growth;
        if b.round() as usize > max {
            out.push(max);
            break;
        }
    }
    out.dedup();
    out
}

/// Run a solver as a black box over the budget schedule. `run(budget)`
/// must solve *from scratch* and return `(objective, metric)`.
pub fn black_box_curve<F>(solver: &str, budgets: &[usize], mut run: F) -> SolverCurve
where
    F: FnMut(usize) -> (f64, f64),
{
    let mut points = Vec::with_capacity(budgets.len());
    for &budget in budgets {
        let t0 = std::time::Instant::now();
        let (objective, metric) = run(budget);
        points.push(BenchPoint {
            budget,
            time: t0.elapsed().as_secs_f64(),
            objective,
            metric,
        });
    }
    SolverCurve { solver: solver.to_string(), points }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_increasing_and_caps_at_max() {
        let s = budget_schedule(100, 1.6);
        assert_eq!(s[0], 1);
        assert_eq!(*s.last().unwrap(), 100);
        for w in s.windows(2) {
            assert!(w[1] > w[0], "{s:?}");
        }
    }

    #[test]
    fn curve_records_all_budgets() {
        let budgets = [1, 2, 4];
        let c = black_box_curve("toy", &budgets, |b| (1.0 / b as f64, 1.0 / b as f64));
        assert_eq!(c.points.len(), 3);
        assert_eq!(c.points[2].budget, 4);
        assert!(c.points[2].metric < c.points[0].metric);
    }

    #[test]
    fn time_to_and_best_within() {
        let c = SolverCurve {
            solver: "s".into(),
            points: vec![
                BenchPoint { budget: 1, time: 0.1, objective: 1.0, metric: 0.5 },
                BenchPoint { budget: 2, time: 0.3, objective: 0.5, metric: 0.01 },
            ],
        };
        assert_eq!(c.time_to(0.1), Some(0.3));
        assert_eq!(c.time_to(1e-9), None);
        assert_eq!(c.best_within(0.2), Some(0.5));
        assert_eq!(c.best_within(0.05), None);
    }

    fn curve(points: Vec<BenchPoint>) -> SolverCurve {
        SolverCurve { solver: "s".into(), points }
    }

    fn pt(time: f64, metric: f64) -> BenchPoint {
        BenchPoint { budget: 1, time, objective: 0.0, metric }
    }

    #[test]
    fn envelope_of_empty_curve_is_empty() {
        assert!(curve(vec![]).monotone_envelope().is_empty());
    }

    #[test]
    fn envelope_of_single_point_is_that_point() {
        assert_eq!(curve(vec![pt(0.25, 0.5)]).monotone_envelope(), vec![(0.25, 0.5)]);
    }

    #[test]
    fn envelope_drops_non_finite_samples_instead_of_panicking() {
        // NaN time previously panicked partial_cmp().unwrap(); a NaN/inf
        // metric would have leaked into the cumulative minimum
        let c = curve(vec![
            pt(f64::NAN, 0.1),
            pt(0.1, f64::NAN),
            pt(0.2, f64::INFINITY),
            pt(0.3, 0.4),
            pt(0.4, 0.2),
        ]);
        let env = c.monotone_envelope();
        assert_eq!(env, vec![(0.3, 0.4), (0.4, 0.2)]);
    }

    #[test]
    fn time_to_target_edge_cases() {
        // empty curve: no time
        assert_eq!(curve(vec![]).time_to_target(1.0), None);
        // single point at the target counts (<=, not <)
        assert_eq!(curve(vec![pt(0.5, 1.0)]).time_to_target(1.0), Some(0.5));
        // never reaches the target
        assert_eq!(curve(vec![pt(0.1, 0.9), pt(0.2, 0.8)]).time_to_target(0.5), None);
        // earliest qualifying time wins even when sampled out of order
        let c = curve(vec![pt(0.9, 0.01), pt(0.2, 0.05), pt(0.5, 0.02)]);
        assert_eq!(c.time_to_target(0.05), Some(0.2));
        // a diverged rerun (NaN time) at the target must not win or poison
        let c = curve(vec![pt(f64::NAN, 0.0), pt(0.7, 0.0)]);
        assert_eq!(c.time_to_target(0.1), Some(0.7));
        // NaN metric never qualifies
        assert_eq!(curve(vec![pt(0.1, f64::NAN)]).time_to_target(1.0), None);
        // the alias stays in sync
        assert_eq!(c.time_to(0.1), c.time_to_target(0.1));
    }

    #[test]
    fn envelope_is_monotone() {
        let c = SolverCurve {
            solver: "s".into(),
            points: vec![
                BenchPoint { budget: 2, time: 0.3, objective: 0.0, metric: 0.2 },
                BenchPoint { budget: 1, time: 0.1, objective: 0.0, metric: 0.5 },
                BenchPoint { budget: 3, time: 0.2, objective: 0.0, metric: 0.9 }, // noisy rerun
            ],
        };
        let env = c.monotone_envelope();
        assert_eq!(env.len(), 3);
        for w in env.windows(2) {
            assert!(w[1].1 <= w[0].1);
            assert!(w[1].0 >= w[0].0);
        }
    }
}
