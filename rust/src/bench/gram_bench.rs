//! Bench scenario `gram`: the Gram-domain inner engine measured against
//! the residual engine and the auto dispatcher over an n/p/|ws|/density
//! grid, with per-stage attribution (epochs vs stationarity scoring vs
//! extrapolation vs Gram assembly) from [`crate::solver::InnerProfile`].
//!
//! What the JSON certifies (ISSUE 5 acceptance):
//! - the **flop-counter ratio** `residual_total / engine_total` per cell —
//!   the engine comparison that holds even where wall time is too noisy
//!   to measure (CI containers);
//! - `auto_ok` per cell: the auto dispatcher's modelled+measured cost is
//!   never worse than **both** fixed choices;
//! - warm-path reuse: per-λ Gram assembly flops along a screened path
//!   sweep sharing one store — later points reuse earlier blocks, so the
//!   series decays instead of repaying the full assembly each λ.
//!
//! Results land in `results/gram/` and — the perf-trajectory anchor —
//! `BENCH_gram.json` at the repo root (skipped when `SKGLM_RESULTS`
//! redirects outputs, e.g. under `cargo test`).

use crate::bench::figures::Scale;
use crate::bench::report::{ensure_dir, results_dir, write_markdown};
use crate::data::{correlated, sparse, CorrelatedSpec, Dataset, SparseSpec};
use crate::datafit::Quadratic;
use crate::estimators::linear::quadratic_lambda_max;
use crate::solver::{solve, FitResult, InnerEngine, SolverOpts};
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::Result;
use std::path::PathBuf;
use std::time::Instant;

/// One (workload, engine) measurement.
#[derive(Clone, Debug)]
pub struct GramBenchRow {
    /// workload shape, e.g. `d1000x300` or `s2000x5000@1e-2`
    pub shape: String,
    pub lam_div: f64,
    /// `residual` | `gram` | `auto`
    pub engine: String,
    pub wall_s: f64,
    pub n_outer: usize,
    pub epochs: usize,
    pub gram_epochs: usize,
    pub residual_epochs: usize,
    pub epoch_flops: f64,
    pub assembly_flops: f64,
    pub total_flops: f64,
    pub epoch_secs: f64,
    pub score_secs: f64,
    pub extrapolation_secs: f64,
    pub assembly_secs: f64,
    pub kkt: f64,
    pub support: usize,
    /// residual engine's total flops / this engine's (>1 ⇒ this wins)
    pub flop_ratio_vs_residual: f64,
    /// auto rows: modelled cost not worse than both fixed engines
    pub auto_ok: bool,
}

fn run_engine(ds: &Dataset, lam: f64, engine: InnerEngine) -> (FitResult, f64) {
    let mut f = Quadratic::new();
    let opts = SolverOpts::default().with_tol(1e-8).with_inner(engine);
    let t0 = Instant::now();
    let r = solve(&ds.design, &ds.y, &mut f, &crate::penalty::L1::new(lam), &opts, None, None);
    (r, t0.elapsed().as_secs_f64())
}

fn engine_name(e: InnerEngine) -> &'static str {
    match e {
        InnerEngine::Auto => "auto",
        InnerEngine::Residual => "residual",
        InnerEngine::Gram => "gram",
    }
}

/// Run the inner-engine grid and persist `BENCH_gram.json`.
pub fn run_gram(scale: Scale) -> Result<Vec<PathBuf>> {
    // (n, p, λ divisors): n ≫ |ws| cells are where Gram must win
    let dense_shapes: Vec<(usize, usize, Vec<f64>)> = match scale {
        Scale::Smoke => vec![(600, 150, vec![10.0]), (200, 400, vec![5.0])],
        Scale::Full => vec![
            (2000, 500, vec![10.0, 50.0]),
            (5000, 400, vec![10.0, 100.0]),
            (500, 2000, vec![10.0, 50.0]),
        ],
    };
    let sparse_shapes: Vec<(usize, usize, f64, Vec<f64>)> = match scale {
        Scale::Smoke => vec![(1500, 3000, 5e-3, vec![20.0])],
        Scale::Full => {
            vec![(5000, 20_000, 1e-3, vec![20.0]), (5000, 20_000, 1e-2, vec![20.0])]
        }
    };

    let engines = [InnerEngine::Residual, InnerEngine::Gram, InnerEngine::Auto];
    let mut rows: Vec<GramBenchRow> = Vec::new();
    let mut auto_never_worst = true;

    let mut bench_cell = |ds: &Dataset, shape: &str, lam_div: f64, rows: &mut Vec<GramBenchRow>| {
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / lam_div;
        let mut cell: Vec<GramBenchRow> = Vec::new();
        for &engine in &engines {
            let (r, wall) = run_engine(ds, lam, engine);
            let p = &r.profile;
            cell.push(GramBenchRow {
                shape: shape.to_string(),
                lam_div,
                engine: engine_name(engine).to_string(),
                wall_s: wall,
                n_outer: r.n_outer,
                epochs: r.n_epochs,
                gram_epochs: p.gram_epochs,
                residual_epochs: p.residual_epochs,
                epoch_flops: p.epoch_flops,
                assembly_flops: p.gram_assembly_flops,
                total_flops: p.total_flops(),
                epoch_secs: p.epoch_secs,
                score_secs: p.score_secs,
                extrapolation_secs: p.extrapolation_secs,
                assembly_secs: p.gram_assembly_secs,
                kkt: r.kkt,
                support: r.support().len(),
                flop_ratio_vs_residual: 1.0, // filled below
                auto_ok: true,
            });
        }
        let residual_total = cell[0].total_flops;
        let fixed_worst = cell[0].total_flops.max(cell[1].total_flops);
        for row in cell.iter_mut() {
            row.flop_ratio_vs_residual = residual_total / row.total_flops.max(1.0);
        }
        // the dispatcher may never end up worse than BOTH fixed choices
        // (1.05: epoch-count noise between runs, not model error)
        let auto_ok = cell[2].total_flops <= fixed_worst * 1.05;
        cell[2].auto_ok = auto_ok;
        auto_never_worst &= auto_ok;
        rows.extend(cell);
    };

    for (n, p, divs) in &dense_shapes {
        let ds = correlated(
            CorrelatedSpec { n: *n, p: *p, rho: 0.5, nnz: (p / 20).max(1), snr: 8.0 },
            42,
        );
        for &div in divs {
            bench_cell(&ds, &format!("d{n}x{p}"), div, &mut rows);
        }
    }
    for (n, p, density, divs) in &sparse_shapes {
        let ds = sparse(
            "gram",
            SparseSpec { n: *n, p: *p, density: *density, support_frac: 0.002, snr: 5.0, binary: false },
            7,
        );
        for &div in divs {
            bench_cell(&ds, &format!("s{n}x{p}@{density:e}"), div, &mut rows);
        }
    }

    // ---- warm-path block reuse (screened sweep, one shared store) ----
    let path_ds = match scale {
        Scale::Smoke => correlated(CorrelatedSpec { n: 400, p: 120, rho: 0.5, nnz: 8, snr: 8.0 }, 11),
        Scale::Full => correlated(CorrelatedSpec { n: 2000, p: 600, rho: 0.5, nnz: 40, snr: 8.0 }, 11),
    };
    let n_points = match scale {
        Scale::Smoke => 6,
        Scale::Full => 12,
    };
    let lam_max = quadratic_lambda_max(&path_ds.design, &path_ds.y);
    let ratios = crate::estimators::path::geometric_grid(1e-2, n_points);
    let opts = SolverOpts::default().with_tol(1e-8).with_inner(InnerEngine::Gram);
    let mut cont = crate::solver::ContinuationState::default();
    let mut work = crate::solver::screening::ScreenWorkspace::new();
    let sq = path_ds.design.col_sq_norms();
    // warm sweep: ONE shared store, per-λ incremental assembly deltas
    let mut path_assembly: Vec<f64> = Vec::new();
    let mut prev_flops = 0u64;
    for &ratio in &ratios {
        // geometric_grid is descending in ratio: warm starts flow
        // from high λ (sparse) to low λ (dense), exactly like Job::Path
        let lam = lam_max * ratio;
        let _ = crate::solver::screening::solve_lasso_screened_warm_with(
            &path_ds.design,
            &path_ds.y,
            lam,
            &opts,
            &mut cont,
            Some(&sq),
            &mut work,
        );
        let total = cont.gram.as_ref().map(|g| g.assembly_flops()).unwrap_or(0);
        path_assembly.push((total - prev_flops) as f64);
        prev_flops = total;
    }
    let warm_assembly: f64 = path_assembly.iter().sum();
    // cold reference: the same sweep with a fresh store at every λ
    let mut cold_assembly = 0.0f64;
    {
        let mut cont_cold = crate::solver::ContinuationState::default();
        let mut work_cold = crate::solver::screening::ScreenWorkspace::new();
        for &ratio in &ratios {
            cont_cold.gram = None; // drop the store: every point reassembles
            let _ = crate::solver::screening::solve_lasso_screened_warm_with(
                &path_ds.design,
                &path_ds.y,
                lam_max * ratio,
                &opts,
                &mut cont_cold,
                Some(&sq),
                &mut work_cold,
            );
            cold_assembly +=
                cont_cold.gram.as_ref().map(|g| g.assembly_flops()).unwrap_or(0) as f64;
        }
    }
    let reuse_ok = warm_assembly < cold_assembly;

    // ---- report ----
    let mut t = Table::new(&[
        "shape", "lam_div", "engine", "wall_s", "outer", "epochs", "gram_ep", "resid_ep",
        "epoch_Mflop", "asm_Mflop", "flop_ratio", "epoch_s", "score_s", "extrap_s", "asm_s",
        "support", "auto_ok",
    ]);
    for r in &rows {
        t.row(vec![
            r.shape.clone(),
            format!("{}", r.lam_div),
            r.engine.clone(),
            format!("{:.4}", r.wall_s),
            r.n_outer.to_string(),
            r.epochs.to_string(),
            r.gram_epochs.to_string(),
            r.residual_epochs.to_string(),
            format!("{:.2}", r.epoch_flops / 1e6),
            format!("{:.2}", r.assembly_flops / 1e6),
            format!("{:.2}x", r.flop_ratio_vs_residual),
            format!("{:.4}", r.epoch_secs),
            format!("{:.4}", r.score_secs),
            format!("{:.4}", r.extrapolation_secs),
            format!("{:.4}", r.assembly_secs),
            r.support.to_string(),
            r.auto_ok.to_string(),
        ]);
    }
    let md = write_markdown("gram", "inner_engines", &t)?;

    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .with("shape", r.shape.as_str())
                .with("lam_div", r.lam_div)
                .with("engine", r.engine.as_str())
                .with("wall_s", r.wall_s)
                .with("n_outer", r.n_outer)
                .with("epochs", r.epochs)
                .with("gram_epochs", r.gram_epochs)
                .with("residual_epochs", r.residual_epochs)
                .with("epoch_flops", r.epoch_flops)
                .with("assembly_flops", r.assembly_flops)
                .with("total_flops", r.total_flops)
                .with("flop_ratio_vs_residual", r.flop_ratio_vs_residual)
                .with("epoch_secs", r.epoch_secs)
                .with("score_secs", r.score_secs)
                .with("extrapolation_secs", r.extrapolation_secs)
                .with("assembly_secs", r.assembly_secs)
                .with("kkt", r.kkt)
                .with("support", r.support)
                .with("auto_ok", r.auto_ok)
        })
        .collect();
    let json = Json::obj()
        .with("bench", "gram")
        .with(
            "scale",
            match scale {
                Scale::Smoke => "smoke",
                Scale::Full => "full",
            },
        )
        .with("rows", Json::Arr(jrows))
        .with("auto_never_worst", auto_never_worst)
        .with("path_assembly_flops_per_lambda", path_assembly.clone())
        .with("path_warm_assembly_flops", warm_assembly)
        .with("path_cold_assembly_flops", cold_assembly)
        .with("path_reuse_ok", reuse_ok);

    let dir = results_dir().join("gram");
    ensure_dir(&dir)?;
    let json_path = dir.join("BENCH_gram.json");
    std::fs::write(&json_path, json.render())?;
    let mut outputs = vec![json_path, md];
    // the repo-root trajectory file (skipped when results are redirected,
    // e.g. by tests)
    if std::env::var_os("SKGLM_RESULTS").is_none() {
        let root = PathBuf::from("BENCH_gram.json");
        std::fs::write(&root, json.render())?;
        outputs.push(root);
    }

    // headline: biggest Gram flop win on a tall cell
    if let Some(best) = rows
        .iter()
        .filter(|r| r.engine == "gram")
        .max_by(|a, b| a.flop_ratio_vs_residual.partial_cmp(&b.flop_ratio_vs_residual).unwrap())
    {
        eprintln!(
            "[gram] {} λmax/{}: Gram engine = {:.1}x fewer modelled flops than residual \
             (auto never worse than both: {auto_never_worst}, path reuse ok: {reuse_ok})",
            best.shape, best.lam_div, best.flop_ratio_vs_residual
        );
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_runs_and_persists_json() {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_gram_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let out = run_gram(Scale::Smoke).unwrap();
        assert!(!out.is_empty());
        for p in &out {
            assert!(p.exists(), "{}", p.display());
        }
        let raw = std::fs::read_to_string(&out[0]).unwrap();
        assert!(raw.contains("\"bench\":\"gram\""));
        assert!(raw.contains("\"engine\":\"gram\""));
        assert!(raw.contains("\"engine\":\"residual\""));
        assert!(raw.contains("\"engine\":\"auto\""));
        // the acceptance-criteria booleans are recorded — and hold at
        // smoke scale (deterministic workloads)
        assert!(raw.contains("\"auto_never_worst\":true"), "{raw}");
        assert!(raw.contains("\"path_reuse_ok\":true"), "{raw}");
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn gram_wins_flops_when_n_dominates_ws() {
        let _guard = crate::bench::report::results_env_lock();
        // tall dense cell: the Gram engine must touch far fewer entries
        let ds = correlated(CorrelatedSpec { n: 800, p: 100, rho: 0.5, nnz: 6, snr: 8.0 }, 5);
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
        let (res, _) = run_engine(&ds, lam, InnerEngine::Residual);
        let (gram, _) = run_engine(&ds, lam, InnerEngine::Gram);
        assert!(res.converged && gram.converged);
        assert!(
            gram.profile.total_flops() < res.profile.total_flops(),
            "gram {} flops should beat residual {} on n≫|ws|",
            gram.profile.total_flops(),
            res.profile.total_flops()
        );
    }
}
