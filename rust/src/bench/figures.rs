//! Experiment runners: one function per paper figure/table. Each runs the
//! workload through the black-box harness and writes CSV/JSON/markdown
//! into `results/<figure>/`. `Scale::Smoke` shrinks datasets and budgets
//! for tests and quick runs; `Scale::Full` is the EXPERIMENTS.md
//! configuration.

use crate::bench::harness::{black_box_curve, budget_schedule, SolverCurve};
use crate::bench::report::{summary_table, write_curves, write_markdown};
use crate::data::meeg::{localize, simulate, MeegSpec};
use crate::data::{correlated, paper_dataset, paper_dataset_small, CorrelatedSpec, Dataset};
use crate::datafit::{Datafit, Quadratic};
use crate::estimators::linear::quadratic_lambda_max;
use crate::estimators::multitask::{block_lambda_max, flatten_tasks, unflatten_coef};
use crate::estimators::path::{geometric_grid, lasso_path, lq_path, mcp_path, scad_path};
use crate::estimators::{BlockMcpRegressor, MultiTaskLasso};
use crate::penalty::{L1L2, Mcp, Penalty, L1};
use crate::solver::baselines::{
    admm::solve_admm, celer::solve_celer, fireworks::solve_fireworks,
    irls::solve_irls_mcp, lbfgs::solve_lbfgs_svm, pgd::solve_pgd,
    strong_rules::solve_strong_rules_enet,
};
use crate::solver::{solve, SolverOpts};
use crate::util::table::{sci, Table};
use anyhow::Result;
use std::path::PathBuf;

/// Experiment scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// tiny datasets + short budgets (tests, CI)
    Smoke,
    /// the EXPERIMENTS.md configuration
    Full,
}

impl Scale {
    fn max_budget(&self, full: usize) -> usize {
        match self {
            Scale::Smoke => (full / 8).max(4),
            Scale::Full => full,
        }
    }

    fn dataset(&self, name: &str, seed: u64) -> Option<Dataset> {
        match self {
            Scale::Smoke => paper_dataset_small(name, seed),
            Scale::Full => paper_dataset(name, seed),
        }
    }
}

fn residual(design: &crate::linalg::Design, y: &[f64], beta: &[f64]) -> Vec<f64> {
    let mut xb = vec![0.0; design.nrows()];
    design.matvec(beta, &mut xb);
    y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect()
}

/// Normalised Lasso gap: gap / P(0) so curves start near 1 (the paper's
/// "normalized duality gap" y-axis).
fn norm_lasso_gap(ds: &Dataset, beta: &[f64], lam: f64) -> f64 {
    let r = residual(&ds.design, &ds.y, beta);
    let p0 = crate::linalg::sq_nrm2(&ds.y) / (2.0 * ds.n() as f64);
    crate::metrics::lasso_gap(&ds.design, &ds.y, beta, &r, lam) / p0.max(1e-300)
}

fn norm_enet_gap(ds: &Dataset, beta: &[f64], lam: f64, rho: f64) -> f64 {
    let r = residual(&ds.design, &ds.y, beta);
    let p0 = crate::linalg::sq_nrm2(&ds.y) / (2.0 * ds.n() as f64);
    crate::metrics::enet_gap(&ds.design, &ds.y, beta, &r, lam, rho) / p0.max(1e-300)
}

// ------------------------------------------------------------- Figure 1 --

/// Regularization paths of L1 / MCP / SCAD / ℓ0.5 on the correlated
/// design: support recovery, estimation error, prediction error per λ.
pub fn run_fig1(scale: Scale) -> Result<Vec<PathBuf>> {
    let spec = match scale {
        Scale::Smoke => CorrelatedSpec::figure1(0.06),
        Scale::Full => CorrelatedSpec::figure1(1.0),
    };
    let ds = correlated(spec, 42);
    // paper normalises columns for the non-convex penalties; use one
    // normalised design throughout so β* stays comparable (‖X_j‖=√n keeps
    // the planted coefficients' scale)
    let mut design = ds.design.clone();
    design.normalize_cols((ds.n() as f64).sqrt());
    let n_points = match scale {
        Scale::Smoke => 8,
        Scale::Full => 30,
    };
    let ratios = geometric_grid(1e-3, n_points);
    let opts = SolverOpts::default().with_tol(1e-7);

    let paths = vec![
        lasso_path(&design, &ds.y, Some(&ds.beta_true), &ratios, &opts),
        mcp_path(&design, &ds.y, Some(&ds.beta_true), &ratios, 3.0, &opts),
        scad_path(&design, &ds.y, Some(&ds.beta_true), &ratios, 3.7, &opts),
        lq_path(&design, &ds.y, Some(&ds.beta_true), &ratios, 0.5, &opts),
    ];

    let mut t = Table::new(&[
        "penalty", "lambda_ratio", "support", "tp", "fp", "estimation_err", "prediction_mse",
    ]);
    for path in &paths {
        for pt in &path.points {
            let rec = pt.recovery.as_ref().unwrap();
            t.row(vec![
                path.penalty_name.clone(),
                format!("{:.4e}", pt.lambda_ratio),
                pt.support_size.to_string(),
                rec.true_positives.to_string(),
                rec.false_positives.to_string(),
                sci(pt.estimation_error.unwrap()),
                sci(pt.prediction_mse.unwrap()),
            ]);
        }
    }
    let dir = crate::bench::report::results_dir().join("fig1");
    crate::bench::report::ensure_dir(&dir)?;
    std::fs::write(dir.join("paths.csv"), t.csv())?;

    // headline summary: best-λ agreement + exact recovery per penalty
    let mut s = Table::new(&[
        "penalty",
        "exact_recovery_anywhere",
        "best_est_lambda_ratio",
        "best_pred_lambda_ratio",
        "best_estimation_err",
        "path_time_s",
    ]);
    for path in &paths {
        let be = path.best_estimation().unwrap();
        let bp = path.best_prediction().unwrap();
        s.row(vec![
            path.penalty_name.clone(),
            path.any_exact_recovery().to_string(),
            format!("{:.4e}", be.lambda_ratio),
            format!("{:.4e}", bp.lambda_ratio),
            sci(be.estimation_error.unwrap()),
            format!("{:.2}", path.total_time),
        ]);
    }
    let md = write_markdown("fig1", "summary", &s)?;
    Ok(vec![dir.join("paths.csv"), md])
}

// ------------------------------------------------------------- Figure 2 --

/// Lasso: normalised duality gap vs time; solvers sklearn-CD / celer-like /
/// blitz-fireworks-like / skglm, multiple datasets × λ ratios.
pub fn run_fig2(scale: Scale) -> Result<Vec<PathBuf>> {
    let datasets: &[&str] = match scale {
        Scale::Smoke => &["rcv1"],
        Scale::Full => &["rcv1", "news20", "finance", "url"],
    };
    let lam_divs: &[f64] = match scale {
        Scale::Smoke => &[10.0, 100.0],
        Scale::Full => &[10.0, 100.0, 1000.0],
    };
    let budgets = budget_schedule(scale.max_budget(60), 1.7);
    let mut outputs = Vec::new();

    for name in datasets {
        let ds = scale.dataset(name, 7).expect("known dataset");
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        for &div in lam_divs {
            let lam = lam_max / div;
            let pen = L1::new(lam);
            let curves = vec![
                black_box_curve("sklearn_cd", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let mut opts = SolverOpts::default().with_tol(1e-12).without_ws().without_acceleration();
                    opts.max_outer = 1;
                    opts.max_epochs = b * 10;
                    opts.inner_tol_ratio = 0.0;
                    let r = solve_full_cd_budget(&ds, &pen, &mut f, &opts);
                    (r.objective, norm_lasso_gap(&ds, &r.beta, lam))
                }),
                black_box_curve("celer_like", &budgets, |b| {
                    let mut opts = SolverOpts::default().with_tol(1e-14);
                    opts.max_outer = b;
                    let r = solve_celer(&ds.design, &ds.y, lam, &opts);
                    (r.objective, norm_lasso_gap(&ds, &r.beta, lam))
                }),
                black_box_curve("blitz_fireworks_like", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let mut opts = SolverOpts::default().with_tol(1e-14);
                    opts.max_outer = b;
                    let r = solve_fireworks(&ds.design, &ds.y, &mut f, &pen, &opts);
                    (r.objective, norm_lasso_gap(&ds, &r.beta, lam))
                }),
                black_box_curve("skglm", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let mut opts = SolverOpts::default().with_tol(1e-14);
                    opts.max_outer = b;
                    let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
                    (r.objective, norm_lasso_gap(&ds, &r.beta, lam))
                }),
            ];
            outputs.push(write_curves("fig2", name, &format!("lmax_over_{div}"), &curves)?);
            let summary = summary_table(&curves, &[1e-4, 1e-6, 1e-9]);
            outputs.push(write_markdown(
                "fig2",
                &format!("{name}_lmax_over_{div}_summary"),
                &summary,
            )?);
        }
    }
    Ok(outputs)
}

/// full-CD run where `opts` already encodes the budget.
fn solve_full_cd_budget(
    ds: &Dataset,
    pen: &impl Penalty,
    f: &mut Quadratic,
    opts: &SolverOpts,
) -> crate::solver::FitResult {
    solve(&ds.design, &ds.y, f, pen, opts, None, None)
}

// ------------------------------------------------------------- Figure 3 --

/// Elastic net (ρ=0.5): sklearn-CD vs vanilla CD vs FISTA vs skglm.
pub fn run_fig3(scale: Scale) -> Result<Vec<PathBuf>> {
    let datasets: &[&str] = match scale {
        Scale::Smoke => &["rcv1"],
        Scale::Full => &["rcv1", "news20", "finance"],
    };
    let lam_divs: &[f64] = match scale {
        Scale::Smoke => &[10.0, 1000.0],
        Scale::Full => &[10.0, 100.0, 1000.0],
    };
    let rho = 0.5;
    let budgets = budget_schedule(scale.max_budget(60), 1.7);
    let mut outputs = Vec::new();

    for name in datasets {
        let ds = scale.dataset(name, 11).expect("known dataset");
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y) / rho;
        for &div in lam_divs {
            let lam = lam_max / div;
            let pen = L1L2::new(lam, rho);
            let curves = vec![
                black_box_curve("sklearn_cd", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let mut opts = SolverOpts::default().with_tol(1e-12).without_ws().without_acceleration();
                    opts.max_outer = 1;
                    opts.max_epochs = b * 10;
                    opts.inner_tol_ratio = 0.0;
                    let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
                    (r.objective, norm_enet_gap(&ds, &r.beta, lam, rho))
                }),
                black_box_curve("fista", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let r = solve_pgd(&ds.design, &ds.y, &mut f, &pen, b * 10, 1e-14, true);
                    (r.objective, norm_enet_gap(&ds, &r.beta, lam, rho))
                }),
                black_box_curve("skglm", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let mut opts = SolverOpts::default().with_tol(1e-14);
                    opts.max_outer = b;
                    let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
                    (r.objective, norm_enet_gap(&ds, &r.beta, lam, rho))
                }),
            ];
            outputs.push(write_curves("fig3", name, &format!("lmax_over_{div}"), &curves)?);
        }
    }
    Ok(outputs)
}

// ------------------------------------------------------------- Figure 4 --

/// M/EEG source localisation: ℓ2,1 vs block-MCP (and block-SCAD) on the
/// simulated right-auditory dataset; reports hemisphere hits, support
/// sizes, position errors.
pub fn run_fig4(scale: Scale) -> Result<Vec<PathBuf>> {
    let spec = match scale {
        Scale::Smoke => MeegSpec { n_sensors: 40, n_sources: 150, n_times: 10, ..Default::default() },
        Scale::Full => MeegSpec::default(),
    };
    let pb = simulate(spec, 42);
    let design = crate::linalg::Design::Dense(pb.gain.clone());
    let y = flatten_tasks(&pb.measurements);
    let t_count = pb.measurements.ncols();
    let lam_max = block_lambda_max(&design, &y, t_count);

    let mut table = Table::new(&[
        "penalty", "lambda_ratio", "active_rows", "hemispheres", "max_position_err", "converged",
    ]);
    // block-MCP/SCAD semi-convexity: γ > 1/L_j = n_sensors for the
    // unit-norm leadfield
    let gamma = 2.5 * pb.gain.nrows() as f64;
    for &ratio in &[0.5, 0.3, 0.2] {
        let lam = lam_max * ratio;
        let l21 = MultiTaskLasso::new(lam).with_tol(1e-6).fit(&design, &y, t_count);
        let mcp = BlockMcpRegressor::new(lam, gamma).with_tol(1e-6).fit(&design, &y, t_count);
        let scad = crate::estimators::multitask::BlockScadRegressor::new(lam, gamma)
            .fit(&design, &y, t_count);
        for (name, fit) in [("l21", &l21), ("block_mcp", &mcp), ("block_scad", &scad)] {
            let loc = localize(&pb, &unflatten_coef(&fit.w, t_count), 1e-6);
            table.row(vec![
                name.to_string(),
                format!("{ratio}"),
                loc.recovered.len().to_string(),
                loc.hemispheres_hit.to_string(),
                if loc.max_position_error.is_finite() {
                    format!("{:.4}", loc.max_position_error)
                } else {
                    "missed".to_string()
                },
                fit.converged.to_string(),
            ]);
        }
    }
    let md = write_markdown("fig4", "localization", &table)?;
    Ok(vec![md])
}

// ------------------------------------------------------------- Figure 5 --

/// MCP regression: objective and stationarity vs time; picasso-like full
/// CD, reweighted-ℓ1 and skglm on the dense simulated dataset + rcv1.
pub fn run_fig5(scale: Scale) -> Result<Vec<PathBuf>> {
    let mut workloads: Vec<(String, Dataset)> = Vec::new();
    let dense_spec = match scale {
        Scale::Smoke => CorrelatedSpec { n: 120, p: 400, rho: 0.5, nnz: 20, snr: 8.0 },
        Scale::Full => CorrelatedSpec { n: 1000, p: 5000, rho: 0.5, nnz: 100, snr: 8.0 },
    };
    workloads.push(("simulated_dense".into(), correlated(dense_spec, 3)));
    workloads.push(("rcv1".into(), scale.dataset("rcv1", 3).unwrap()));

    let lam_divs: &[f64] = match scale {
        Scale::Smoke => &[10.0],
        Scale::Full => &[10.0, 100.0],
    };
    let gamma = 3.0;
    let budgets = budget_schedule(scale.max_budget(50), 1.7);
    let mut outputs = Vec::new();

    for (name, ds) in &workloads {
        // paper: columns normalised to √n for MCP
        let mut design = ds.design.clone();
        design.normalize_cols((ds.n() as f64).sqrt());
        let norm_ds = Dataset {
            name: ds.name.clone(),
            design,
            y: ds.y.clone(),
            beta_true: ds.beta_true.clone(),
        };
        let lam_max = quadratic_lambda_max(&norm_ds.design, &norm_ds.y);
        for &div in lam_divs {
            let lam = lam_max / div;
            let pen = Mcp::new(lam, gamma);
            let stat = |beta: &[f64]| {
                let mut f = Quadratic::new();
                f.init(&norm_ds.design, &norm_ds.y);
                let state = f.init_state(&norm_ds.design, &norm_ds.y, beta);
                crate::metrics::stationarity(&norm_ds.design, &norm_ds.y, &f, &pen, beta, &state)
            };
            let curves = vec![
                black_box_curve("picasso_like_cd", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let mut opts = SolverOpts::default().with_tol(1e-12).without_ws().without_acceleration();
                    opts.max_outer = 1;
                    opts.max_epochs = b * 10;
                    opts.inner_tol_ratio = 0.0;
                    let r = solve(&norm_ds.design, &norm_ds.y, &mut f, &pen, &opts, None, None);
                    (r.objective, stat(&r.beta))
                }),
                black_box_curve("reweighted_l1", &budgets, |b| {
                    let mut opts = SolverOpts::default().with_tol(1e-10);
                    opts.max_outer = 20;
                    let rounds = (b / 5).max(1);
                    let r = solve_irls_mcp(&norm_ds.design, &norm_ds.y, lam, gamma, rounds, &opts);
                    (r.objective, stat(&r.beta))
                }),
                black_box_curve("skglm", &budgets, |b| {
                    let mut f = Quadratic::new();
                    let mut opts = SolverOpts::default().with_tol(1e-14);
                    opts.max_outer = b;
                    let r = solve(&norm_ds.design, &norm_ds.y, &mut f, &pen, &opts, None, None);
                    (r.objective, stat(&r.beta))
                }),
            ];
            outputs.push(write_curves("fig5", name, &format!("lmax_over_{div}"), &curves)?);
        }
    }
    Ok(outputs)
}

// ------------------------------------------------------------- Figure 6 --

/// Ablation: working sets × Anderson acceleration (4 combos) on the Lasso.
pub fn run_fig6(scale: Scale) -> Result<Vec<PathBuf>> {
    let datasets: &[&str] = match scale {
        Scale::Smoke => &["rcv1"],
        Scale::Full => &["rcv1", "news20", "finance"],
    };
    let lam_divs: &[f64] = match scale {
        Scale::Smoke => &[10.0, 100.0],
        Scale::Full => &[10.0, 100.0, 1000.0],
    };
    let budgets = budget_schedule(scale.max_budget(60), 1.7);
    let mut outputs = Vec::new();

    for name in datasets {
        let ds = scale.dataset(name, 13).expect("known dataset");
        let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
        for &div in lam_divs {
            let lam = lam_max / div;
            let pen = L1::new(lam);
            let combos: [(&str, bool, usize); 4] = [
                ("no_ws_no_accel", false, 0),
                ("no_ws_accel", false, 5),
                ("ws_no_accel", true, 0),
                ("ws_accel", true, 5),
            ];
            let curves: Vec<SolverCurve> = combos
                .iter()
                .map(|&(label, use_ws, m)| {
                    black_box_curve(label, &budgets, |b| {
                        let mut f = Quadratic::new();
                        let mut opts = SolverOpts::default().with_tol(1e-14);
                        opts.use_ws = use_ws;
                        opts.anderson_m = m;
                        if use_ws {
                            opts.max_outer = b;
                        } else {
                            opts.max_outer = 1;
                            opts.max_epochs = b * 10;
                            opts.inner_tol_ratio = 0.0;
                        }
                        let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
                        (r.objective, norm_lasso_gap(&ds, &r.beta, lam))
                    })
                })
                .collect();
            outputs.push(write_curves("fig6", name, &format!("lmax_over_{div}"), &curves)?);
        }
    }
    Ok(outputs)
}

// ------------------------------------------------------------- Figure 7 --

/// ADMM vs skglm on a synthetic elastic net.
pub fn run_fig7(scale: Scale) -> Result<Vec<PathBuf>> {
    let spec = match scale {
        Scale::Smoke => CorrelatedSpec { n: 100, p: 80, rho: 0.4, nnz: 8, snr: 10.0 },
        Scale::Full => CorrelatedSpec { n: 1000, p: 600, rho: 0.5, nnz: 40, snr: 10.0 },
    };
    let ds = correlated(spec, 17);
    let rho = 0.5;
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / rho / 50.0;
    let pen = L1L2::new(lam, rho);
    let budgets = budget_schedule(scale.max_budget(80), 1.7);

    let curves = vec![
        black_box_curve("admm", &budgets, |b| {
            let r = solve_admm(&ds.design, &ds.y, lam, rho, 1.0, b * 10, 1e-14);
            (r.objective, norm_enet_gap(&ds, &r.beta, lam, rho))
        }),
        black_box_curve("skglm", &budgets, |b| {
            let mut f = Quadratic::new();
            let mut opts = SolverOpts::default().with_tol(1e-14);
            opts.max_outer = b;
            let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
            (r.objective, norm_enet_gap(&ds, &r.beta, lam, rho))
        }),
    ];
    Ok(vec![write_curves("fig7", "synthetic", "lmax_over_50", &curves)?])
}

// ------------------------------------------------------------- Figure 8 --

/// glmnet-like strong-rules path solver vs skglm on a synthetic enet.
pub fn run_fig8(scale: Scale) -> Result<Vec<PathBuf>> {
    let spec = match scale {
        Scale::Smoke => CorrelatedSpec { n: 100, p: 150, rho: 0.5, nnz: 10, snr: 10.0 },
        Scale::Full => CorrelatedSpec { n: 800, p: 2000, rho: 0.5, nnz: 60, snr: 10.0 },
    };
    let ds = correlated(spec, 19);
    let rho = 0.5;
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / rho / 100.0;
    let budgets = budget_schedule(scale.max_budget(60), 1.7);

    let curves = vec![
        black_box_curve("glmnet_like_path", &budgets, |b| {
            // budget controls the per-step epoch allowance; glmnet must
            // traverse the whole path to reach the target λ
            let r = solve_strong_rules_enet(&ds.design, &ds.y, lam, rho, 15, b * 5, 1e-12);
            (r.objective, norm_enet_gap(&ds, &r.beta, lam, rho))
        }),
        black_box_curve("skglm", &budgets, |b| {
            let mut f = Quadratic::new();
            let mut opts = SolverOpts::default().with_tol(1e-14);
            opts.max_outer = b;
            let pen = L1L2::new(lam, rho);
            let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
            (r.objective, norm_enet_gap(&ds, &r.beta, lam, rho))
        }),
    ];
    Ok(vec![write_curves("fig8", "synthetic", "lmax_over_100", &curves)?])
}

// ------------------------------------------------------------- Figure 9 --

/// Dual SVM: suboptimality vs time for C ∈ {0.1, 1, 10}; CD, skglm (dual)
/// and L-BFGS on the squared-hinge primal (each solver's suboptimality is
/// measured against its own problem's reference optimum — see
/// ARCHITECTURE.md §Substitutions).
pub fn run_fig9(scale: Scale) -> Result<Vec<PathBuf>> {
    let ds = scale.dataset("real-sim", 23).expect("real-sim stand-in");
    let x = match &ds.design {
        crate::linalg::Design::Sparse(s) => s.clone(),
        crate::linalg::Design::Dense(_) => unreachable!("real-sim stand-in is sparse"),
    };
    let dual_design = crate::datafit::QuadraticSvc::dual_design_sparse(&x, &ds.y);
    let budgets = budget_schedule(scale.max_budget(50), 1.7);
    let cs: &[f64] = match scale {
        Scale::Smoke => &[1.0],
        Scale::Full => &[0.1, 1.0, 10.0],
    };
    let mut outputs = Vec::new();

    for &c in cs {
        let pen = crate::penalty::BoxIndicator::new(c);
        // reference dual optimum (high precision)
        let mut f_ref = crate::datafit::QuadraticSvc::new();
        let mut ref_opts = SolverOpts::default().with_tol(1e-11);
        ref_opts.max_outer = 400;
        let reference = solve(&dual_design, &ds.y, &mut f_ref, &pen, &ref_opts, None, None);
        let dual_opt = reference.objective;
        // reference primal optimum for the L-BFGS curve
        let lb_ref = solve_lbfgs_svm(&ds.design, &ds.y, c, 10, 3000, 1e-12);
        let primal_opt = lb_ref.objective;

        let curves = vec![
            black_box_curve("cd_dual", &budgets, |b| {
                let mut f = crate::datafit::QuadraticSvc::new();
                let mut opts = SolverOpts::default().with_tol(1e-14).without_ws().without_acceleration();
                opts.max_outer = 1;
                opts.max_epochs = b * 10;
                opts.inner_tol_ratio = 0.0;
                let r = solve(&dual_design, &ds.y, &mut f, &pen, &opts, None, None);
                (r.objective, (r.objective - dual_opt).max(1e-16))
            }),
            black_box_curve("skglm_dual", &budgets, |b| {
                let mut f = crate::datafit::QuadraticSvc::new();
                let mut opts = SolverOpts::default().with_tol(1e-14);
                opts.max_outer = b;
                let r = solve(&dual_design, &ds.y, &mut f, &pen, &opts, None, None);
                (r.objective, (r.objective - dual_opt).max(1e-16))
            }),
            black_box_curve("lbfgs_primal_sqhinge", &budgets, |b| {
                let r = solve_lbfgs_svm(&ds.design, &ds.y, c, 10, b * 5, 1e-16);
                (r.objective, (r.objective - primal_opt).max(1e-16))
            }),
        ];
        outputs.push(write_curves("fig9", "real-sim", &format!("C_{c}"), &curves)?);
    }
    Ok(outputs)
}

// ------------------------------------------------------------ Figure 10 --

/// benchopt-artefact illustration: repeated black-box runs of the same
/// solver produce non-monotone time curves (run-to-run timing noise).
pub fn run_fig10(scale: Scale) -> Result<Vec<PathBuf>> {
    let ds = scale.dataset("rcv1", 29).expect("rcv1 stand-in");
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / 100.0;
    let pen = L1::new(lam);
    let budgets = budget_schedule(scale.max_budget(40), 1.5);
    let reps = match scale {
        Scale::Smoke => 2,
        Scale::Full => 5,
    };
    let curves: Vec<SolverCurve> = (0..reps)
        .map(|rep| {
            let mut c = black_box_curve("sklearn_cd", &budgets, |b| {
                let mut f = Quadratic::new();
                let mut opts = SolverOpts::default().with_tol(1e-12).without_ws().without_acceleration();
                opts.max_outer = 1;
                opts.max_epochs = b * 5;
                opts.inner_tol_ratio = 0.0;
                let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
                (r.objective, norm_lasso_gap(&ds, &r.beta, lam))
            });
            c.solver = format!("sklearn_cd_rep{rep}");
            c
        })
        .collect();
    Ok(vec![write_curves("fig10", "rcv1", "lmax_over_100", &curves)?])
}

// --------------------------------------------------------------- Tables --

/// Table 1: capability matrix (self-checked for our row).
pub fn run_table1() -> Result<Vec<PathBuf>> {
    let t = crate::bench::capability::capability_table();
    Ok(vec![write_markdown("table1", "capabilities", &t)?])
}

/// Table 2: characteristics of the synthetic stand-ins (paper values in
/// ARCHITECTURE.md §Substitutions).
pub fn run_table2(scale: Scale) -> Result<Vec<PathBuf>> {
    let mut t = Table::new(&["dataset", "n_samples", "n_features", "density"]);
    for name in ["rcv1", "news20", "finance", "kdda", "url", "real-sim"] {
        if let Some(ds) = scale.dataset(name, 0) {
            let density = match &ds.design {
                crate::linalg::Design::Sparse(s) => s.density(),
                crate::linalg::Design::Dense(_) => 1.0,
            };
            t.row(vec![
                name.to_string(),
                ds.n().to_string(),
                ds.p().to_string(),
                format!("{density:.2e}"),
            ]);
        }
    }
    Ok(vec![write_markdown("table2", "datasets", &t)?])
}

/// Run a named experiment.
pub fn run_experiment(name: &str, scale: Scale) -> Result<Vec<PathBuf>> {
    match name {
        "fig1" => run_fig1(scale),
        "fig2" => run_fig2(scale),
        "fig3" => run_fig3(scale),
        "fig4" => run_fig4(scale),
        "fig5" => run_fig5(scale),
        "fig6" => run_fig6(scale),
        "fig7" => run_fig7(scale),
        "fig8" => run_fig8(scale),
        "fig9" => run_fig9(scale),
        "fig10" => run_fig10(scale),
        "table1" => run_table1(),
        "table2" => run_table2(scale),
        "pathsched" => crate::bench::path_bench::run_pathsched(scale),
        "kernels" => crate::bench::kernel_bench::run_kernels(scale),
        "glms" => crate::bench::glm_bench::run_glms(scale),
        "groups" => crate::bench::group_bench::run_groups(scale),
        "gram" => crate::bench::gram_bench::run_gram(scale),
        "batch" => crate::bench::batch_bench::run_batch(scale),
        "simd" => crate::bench::simd_bench::run_simd(scale),
        // the static-analysis gate: scale-independent, fails on findings
        "analysis" => crate::analysis::run(std::path::Path::new("."), false),
        // the conformance corpus: Smoke = the CI smoke subset, Full = all
        "scenarios" => {
            crate::bench::scenario::conform(None, None, scale == Scale::Smoke)
        }
        // roll-up of every repo-root BENCH_*.json into BENCH_SUMMARY.json
        // (not part of `all`: it summarises whatever trajectory points
        // exist, it doesn't produce new ones)
        "summary" => {
            crate::bench::report::write_bench_summary(std::path::Path::new(".")).map(|p| vec![p])
        }
        "all" => {
            let mut out = Vec::new();
            for exp in ALL_EXPERIMENTS {
                eprintln!("[exp] running {exp}");
                out.extend(run_experiment(exp, scale)?);
            }
            Ok(out)
        }
        other => anyhow::bail!("unknown experiment {other:?}; try one of {ALL_EXPERIMENTS:?}"),
    }
}

pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "table1",
    "table2", "pathsched", "kernels", "glms", "groups", "gram", "batch", "simd", "analysis",
    "scenarios",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn with_tmp_results<F: FnOnce()>(f: F) {
        let _guard = crate::bench::report::results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_fig_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        f();
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn smoke_fig7_and_table2() {
        with_tmp_results(|| {
            let out = run_fig7(Scale::Smoke).unwrap();
            assert!(!out.is_empty());
            for p in &out {
                assert!(p.exists(), "{}", p.display());
            }
            let out = run_table2(Scale::Smoke).unwrap();
            assert!(out[0].exists());
        });
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("fig99", Scale::Smoke).is_err());
    }
}
