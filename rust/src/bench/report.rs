//! Result emitters: write experiment outputs (markdown tables, CSV series,
//! JSON curves) under `results/`.

use crate::bench::harness::SolverCurve;
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Output directory (override with `SKGLM_RESULTS`).
pub fn results_dir() -> PathBuf {
    std::env::var_os("SKGLM_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

pub fn ensure_dir(p: &Path) -> Result<()> {
    std::fs::create_dir_all(p).with_context(|| format!("creating {}", p.display()))
}

/// Serialises tests that mutate `SKGLM_RESULTS`: env vars are process
/// globals, so concurrent test threads redirecting results would race.
#[cfg(test)]
pub(crate) fn results_env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Persist a family of solver curves for one (figure, dataset, λ) cell:
/// a CSV with one row per point plus a JSON file with the raw curves.
pub fn write_curves(
    figure: &str,
    dataset: &str,
    lambda_label: &str,
    curves: &[SolverCurve],
) -> Result<PathBuf> {
    let dir = results_dir().join(figure);
    ensure_dir(&dir)?;
    let stem = format!("{dataset}_{}", lambda_label.replace('/', "_"));

    let mut t = Table::new(&["solver", "budget", "time_s", "objective", "metric"]);
    for c in curves {
        for p in &c.points {
            t.row(vec![
                c.solver.clone(),
                p.budget.to_string(),
                format!("{:.6}", p.time),
                format!("{:.12e}", p.objective),
                format!("{:.6e}", p.metric),
            ]);
        }
    }
    let csv_path = dir.join(format!("{stem}.csv"));
    std::fs::write(&csv_path, t.csv())?;

    let json = Json::Arr(curves.iter().map(|c| c.to_json()).collect());
    std::fs::write(dir.join(format!("{stem}.json")), json.render())?;
    Ok(csv_path)
}

/// Write a standalone markdown table.
pub fn write_markdown(figure: &str, name: &str, table: &Table) -> Result<PathBuf> {
    let dir = results_dir().join(figure);
    ensure_dir(&dir)?;
    let path = dir.join(format!("{name}.md"));
    std::fs::write(&path, table.markdown())?;
    Ok(path)
}

/// Summarise curves the way the paper's figures read: time to reach each
/// decade of the metric, per solver.
pub fn summary_table(curves: &[SolverCurve], targets: &[f64]) -> Table {
    let mut header: Vec<String> = vec!["solver".to_string()];
    header.extend(targets.iter().map(|t| format!("t@{t:.0e}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    for c in curves {
        let mut row = vec![c.solver.clone()];
        for &tgt in targets {
            row.push(match c.time_to(tgt) {
                Some(t) => format!("{t:.3}s"),
                None => "—".to_string(),
            });
        }
        table.row(row);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::BenchPoint;

    fn curve() -> SolverCurve {
        SolverCurve {
            solver: "skglm".into(),
            points: vec![
                BenchPoint { budget: 1, time: 0.01, objective: 1.0, metric: 1e-2 },
                BenchPoint { budget: 4, time: 0.05, objective: 0.9, metric: 1e-6 },
            ],
        }
    }

    #[test]
    fn writes_csv_and_json() {
        let _guard = results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_report_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let path = write_curves("figX", "toy", "lmax/10", &[curve()]).unwrap();
        assert!(path.exists());
        let csv = std::fs::read_to_string(&path).unwrap();
        assert!(csv.lines().count() == 3, "{csv}");
        let json_path = path.with_extension("json");
        assert!(json_path.exists());
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn summary_table_reports_times_and_misses() {
        let t = summary_table(&[curve()], &[1e-4, 1e-9]);
        let md = t.markdown();
        assert!(md.contains("skglm"));
        assert!(md.contains("—"), "unreached target shown as dash: {md}");
    }
}
