//! Result emitters: write experiment outputs (markdown tables, CSV series,
//! JSON curves) under `results/`.

use crate::bench::harness::SolverCurve;
use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Output directory (override with `SKGLM_RESULTS`).
pub fn results_dir() -> PathBuf {
    std::env::var_os("SKGLM_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

pub fn ensure_dir(p: &Path) -> Result<()> {
    std::fs::create_dir_all(p).with_context(|| format!("creating {}", p.display()))
}

/// Serialises tests that mutate `SKGLM_RESULTS`: env vars are process
/// globals, so concurrent test threads redirecting results would race.
#[cfg(test)]
pub(crate) fn results_env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Persist a family of solver curves for one (figure, dataset, λ) cell:
/// a CSV with one row per point plus a JSON file with the raw curves.
pub fn write_curves(
    figure: &str,
    dataset: &str,
    lambda_label: &str,
    curves: &[SolverCurve],
) -> Result<PathBuf> {
    let dir = results_dir().join(figure);
    ensure_dir(&dir)?;
    let stem = format!("{dataset}_{}", lambda_label.replace('/', "_"));

    let mut t = Table::new(&["solver", "budget", "time_s", "objective", "metric"]);
    for c in curves {
        for p in &c.points {
            t.row(vec![
                c.solver.clone(),
                p.budget.to_string(),
                format!("{:.6}", p.time),
                format!("{:.12e}", p.objective),
                format!("{:.6e}", p.metric),
            ]);
        }
    }
    let csv_path = dir.join(format!("{stem}.csv"));
    std::fs::write(&csv_path, t.csv())?;

    let json = Json::Arr(curves.iter().map(|c| c.to_json()).collect());
    std::fs::write(dir.join(format!("{stem}.json")), json.render())?;
    Ok(csv_path)
}

/// Roll every repo-root `BENCH_*.json` trajectory point up into one
/// `BENCH_SUMMARY.json` in `dir` (keyed by the bench name, contents
/// embedded verbatim) so the perf trajectory is trackable as a single
/// artifact. `skglm exp summary` and CI call this after the bench smokes.
pub fn write_bench_summary(dir: &Path) -> Result<PathBuf> {
    let mut entries: Vec<(String, String)> = Vec::new();
    for e in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let e = e?;
        let name = e.file_name().to_string_lossy().to_string();
        let stem = match name.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json")) {
            Some(s) => s,
            None => continue,
        };
        if stem == "SUMMARY" {
            continue;
        }
        let raw = std::fs::read_to_string(e.path())?;
        let trimmed = raw.trim();
        // only embed balanced JSON — a corrupt/truncated file (killed
        // mid-write) must not poison the whole summary
        if balanced_json(trimmed) {
            entries.push((stem.to_string(), trimmed.to_string()));
        }
    }
    entries.sort();
    let mut benches = Json::obj();
    let names: Vec<Json> = entries.iter().map(|(k, _)| Json::Str(k.clone())).collect();
    for (k, v) in entries {
        benches = benches.with(&k, Json::Raw(v));
    }
    let json = Json::obj()
        .with("summary", "roll-up of repo-root BENCH_*.json perf-trajectory points")
        .with("included", Json::Arr(names))
        .with("benches", benches);
    let path = dir.join("BENCH_SUMMARY.json");
    std::fs::write(&path, json.render())?;
    Ok(path)
}

/// Cheap embeddability check for [`write_bench_summary`] (no JSON parser
/// offline): the text must start like a JSON container, every `{`/`[`
/// must close in order, strings/escapes must terminate, and nothing may
/// trail the closing bracket. Catches truncated writes; not a validator.
fn balanced_json(s: &str) -> bool {
    if !(s.starts_with('{') || s.starts_with('[')) {
        return false;
    }
    let mut depth: Vec<char> = Vec::new();
    let mut in_string = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        if in_string {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_string = false;
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '{' => depth.push('}'),
            '[' => depth.push(']'),
            '}' | ']' => {
                if depth.pop() != Some(c) {
                    return false;
                }
                if depth.is_empty() {
                    // nothing but whitespace may follow the closing bracket
                    return s[i + 1..].trim().is_empty();
                }
            }
            _ => {}
        }
    }
    false // ran out of input with open containers or an open string
}

/// Write a standalone markdown table.
pub fn write_markdown(figure: &str, name: &str, table: &Table) -> Result<PathBuf> {
    let dir = results_dir().join(figure);
    ensure_dir(&dir)?;
    let path = dir.join(format!("{name}.md"));
    std::fs::write(&path, table.markdown())?;
    Ok(path)
}

/// Summarise curves the way the paper's figures read: time to reach each
/// decade of the metric, per solver.
pub fn summary_table(curves: &[SolverCurve], targets: &[f64]) -> Table {
    let mut header: Vec<String> = vec!["solver".to_string()];
    header.extend(targets.iter().map(|t| format!("t@{t:.0e}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    for c in curves {
        let mut row = vec![c.solver.clone()];
        for &tgt in targets {
            row.push(match c.time_to(tgt) {
                Some(t) => format!("{t:.3}s"),
                None => "—".to_string(),
            });
        }
        table.row(row);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::BenchPoint;

    fn curve() -> SolverCurve {
        SolverCurve {
            solver: "skglm".into(),
            points: vec![
                BenchPoint { budget: 1, time: 0.01, objective: 1.0, metric: 1e-2 },
                BenchPoint { budget: 4, time: 0.05, objective: 0.9, metric: 1e-6 },
            ],
        }
    }

    #[test]
    fn writes_csv_and_json() {
        let _guard = results_env_lock();
        let tmp = std::env::temp_dir().join(format!("skglm_report_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &tmp);
        let path = write_curves("figX", "toy", "lmax/10", &[curve()]).unwrap();
        assert!(path.exists());
        let csv = std::fs::read_to_string(&path).unwrap();
        assert!(csv.lines().count() == 3, "{csv}");
        let json_path = path.with_extension("json");
        assert!(json_path.exists());
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn bench_summary_rolls_up_root_trajectory_files() {
        let tmp = std::env::temp_dir().join(format!("skglm_summary_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("BENCH_alpha.json"), r#"{"bench":"alpha","x":1.0}"#).unwrap();
        std::fs::write(tmp.join("BENCH_beta.json"), r#"{"bench":"beta"}"#).unwrap();
        std::fs::write(tmp.join("BENCH_bad.json"), "not json").unwrap();
        // killed mid-write: starts like JSON but is truncated
        std::fs::write(tmp.join("BENCH_cut.json"), r#"{"bench":"cut","rows":["#).unwrap();
        std::fs::write(tmp.join("unrelated.txt"), "x").unwrap();
        let path = write_bench_summary(&tmp).unwrap();
        let raw = std::fs::read_to_string(&path).unwrap();
        assert!(raw.contains(r#""alpha":{"bench":"alpha","x":1.0}"#), "{raw}");
        assert!(raw.contains(r#""beta""#));
        assert!(!raw.contains("not json"), "corrupt file embedded: {raw}");
        assert!(!raw.contains(r#""cut""#), "truncated file embedded: {raw}");
        // idempotent: a second run must not swallow its own output
        let again = std::fs::read_to_string(write_bench_summary(&tmp).unwrap()).unwrap();
        assert!(!again.contains("SUMMARY\":"), "summary embedded itself");
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn balanced_json_accepts_real_and_rejects_truncated() {
        assert!(balanced_json(r#"{"a":[1,{"b":"}"}]}"#));
        assert!(balanced_json("[1,2,3]"));
        assert!(!balanced_json(r#"{"a":[1,2"#), "truncated");
        assert!(!balanced_json(r#"{"a":1}]"#), "mismatched close");
        assert!(!balanced_json(r#"{"a":1} extra"#), "trailing garbage");
        assert!(!balanced_json(r#"{"a":"unterminated}"#), "open string");
        assert!(!balanced_json("plain text"));
    }

    #[test]
    fn summary_table_reports_times_and_misses() {
        let t = summary_table(&[curve()], &[1e-4, 1e-9]);
        let md = t.markdown();
        assert!(md.contains("skglm"));
        assert!(md.contains("—"), "unreached target shown as dash: {md}");
    }
}
