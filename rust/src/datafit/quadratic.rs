//! Quadratic datafit `f(β) = ‖y − Xβ‖² / (2n)` — the Lasso / elastic net /
//! MCP regression loss. The hot case: its state is the residual
//! `r = Xβ − y`, so the CD gradient is `X[:,j]ᵀ r / n` (one sparse dot) and
//! the state update after `β_j += δ` is `r += δ·X[:,j]` (one sparse axpy).

use super::Datafit;
use crate::linalg::Design;

#[derive(Clone, Debug, Default)]
pub struct Quadratic {
    lipschitz: Vec<f64>,
    inv_n: f64,
}

impl Quadratic {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Datafit for Quadratic {
    fn init(&mut self, design: &Design, y: &[f64]) {
        assert_eq!(design.nrows(), y.len());
        let n = design.nrows() as f64;
        self.inv_n = 1.0 / n;
        self.lipschitz = design.col_sq_norms().iter().map(|s| s / n).collect();
    }

    fn init_cached(&mut self, design: &Design, y: &[f64], col_sq_norms: Option<&[f64]>) {
        match col_sq_norms {
            Some(norms) => {
                assert_eq!(design.nrows(), y.len());
                assert_eq!(norms.len(), design.ncols());
                let n = design.nrows() as f64;
                self.inv_n = 1.0 / n;
                self.lipschitz = norms.iter().map(|s| s / n).collect();
            }
            None => self.init(design, y),
        }
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = residual `Xβ − y`.
    fn init_state(&self, design: &Design, y: &[f64], beta: &[f64]) -> Vec<f64> {
        let mut xw = vec![0.0; design.nrows()];
        design.matvec(beta, &mut xw);
        for (r, &yi) in xw.iter_mut().zip(y.iter()) {
            *r -= yi;
        }
        xw
    }

    #[inline]
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]) {
        design.col_axpy(j, delta, state);
    }

    fn value(&self, _y: &[f64], _beta: &[f64], state: &[f64]) -> f64 {
        0.5 * self.inv_n * crate::linalg::sq_nrm2(state)
    }

    #[inline]
    fn grad_j(&self, design: &Design, _y: &[f64], state: &[f64], _beta: &[f64], j: usize) -> f64 {
        self.inv_n * design.col_dot(j, state)
    }

    fn grad_full(
        &self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) {
        design.matvec_t(state, out);
        for g in out.iter_mut() {
            *g *= self.inv_n;
        }
    }

    fn name(&self) -> &'static str {
        "quadratic"
    }

    /// Exact residual quadratic: `∇_j f = X_jᵀ(Xβ − y)/n` — the Gram
    /// inner engine's contract holds with `c = 1/n`.
    fn residual_quadratic_scale(&self) -> Option<f64> {
        Some(self.inv_n)
    }

    fn supports_prox_newton(&self) -> bool {
        true
    }

    /// `F_i(s) = (s − y_i)²/2n`; the state already stores `s − y`, so the
    /// raw gradient is the scaled residual.
    fn raw_grad(&self, _y: &[f64], state: &[f64], out: &mut [f64]) {
        for (o, &r) in out.iter_mut().zip(state.iter()) {
            *o = r * self.inv_n;
        }
    }

    /// Constant curvature `1/n`: prox-Newton's first subproblem is the
    /// full problem, so it converges in one outer iteration.
    fn raw_hessian(&self, _y: &[f64], state: &[f64], out: &mut [f64]) {
        let _ = state;
        for o in out.iter_mut() {
            *o = self.inv_n;
        }
    }

    /// ‖X‖₂²/n via a few power iterations (tight, unlike the Σ L_j default).
    fn global_lipschitz(&self, design: &Design) -> f64 {
        let (n, p) = (design.nrows(), design.ncols());
        let mut v = vec![1.0 / (p as f64).sqrt(); p];
        let mut xv = vec![0.0; n];
        let mut xtxv = vec![0.0; p];
        let mut lam = 0.0;
        for _ in 0..30 {
            design.matvec(&v, &mut xv);
            design.matvec_t(&xv, &mut xtxv);
            lam = crate::linalg::nrm2(&xtxv);
            if lam == 0.0 {
                return 0.0;
            }
            for (vi, &ui) in v.iter_mut().zip(xtxv.iter()) {
                *vi = ui / lam;
            }
        }
        lam / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    fn setup() -> (Design, Vec<f64>, Quadratic) {
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, -1.0],
            vec![0.5, 0.0],
        ]);
        let y = vec![1.0, -1.0, 0.5];
        let d: Design = x.into();
        let mut f = Quadratic::new();
        f.init(&d, &y);
        (d, y, f)
    }

    #[test]
    fn value_matches_formula() {
        let (d, y, f) = setup();
        let beta = vec![0.5, -0.25];
        let state = f.init_state(&d, &y, &beta);
        let mut xb = vec![0.0; 3];
        d.matvec(&beta, &mut xb);
        let expect: f64 =
            xb.iter().zip(y.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / 6.0;
        assert!((f.value(&y, &beta, &state) - expect).abs() < 1e-14);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (d, y, f) = setup();
        let beta = vec![0.3, -0.7];
        let state = f.init_state(&d, &y, &beta);
        let eps = 1e-6;
        for j in 0..2 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let sp = f.init_state(&d, &y, &bp);
            let mut bm = beta.clone();
            bm[j] -= eps;
            let sm = f.init_state(&d, &y, &bm);
            let fd = (f.value(&y, &bp, &sp) - f.value(&y, &bm, &sm)) / (2.0 * eps);
            let an = f.grad_j(&d, &y, &state, &beta, j);
            assert!((fd - an).abs() < 1e-6, "j={j}: fd={fd} an={an}");
        }
    }

    #[test]
    fn grad_full_matches_grad_j() {
        let (d, y, f) = setup();
        let beta = vec![0.3, -0.7];
        let state = f.init_state(&d, &y, &beta);
        let mut full = vec![0.0; 2];
        f.grad_full(&d, &y, &state, &beta, &mut full);
        for j in 0..2 {
            assert!((full[j] - f.grad_j(&d, &y, &state, &beta, j)).abs() < 1e-14);
        }
    }

    #[test]
    fn update_state_tracks_residual() {
        let (d, y, f) = setup();
        let mut beta = vec![0.0, 0.0];
        let mut state = f.init_state(&d, &y, &beta);
        beta[1] = 2.0;
        f.update_state(&d, 1, 2.0, &mut state);
        let fresh = f.init_state(&d, &y, &beta);
        for (a, b) in state.iter().zip(fresh.iter()) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn lipschitz_is_col_norm_over_n() {
        let (d, _, f) = setup();
        let expect: Vec<f64> = d.col_sq_norms().iter().map(|s| s / 3.0).collect();
        assert_eq!(f.lipschitz(), &expect[..]);
    }

    #[test]
    fn global_lipschitz_bounds_coordinate_constants() {
        let (d, _, f) = setup();
        let gl = f.global_lipschitz(&d);
        // ||X||_2^2/n >= max_j ||X_j||^2/n
        let max_lj = f.lipschitz().iter().cloned().fold(0.0, f64::max);
        assert!(gl >= max_lj - 1e-10, "gl={gl} max_lj={max_lj}");
        // and is bounded above by the Frobenius bound
        let frob: f64 = d.col_sq_norms().iter().sum::<f64>() / 3.0;
        assert!(gl <= frob + 1e-10);
    }
}
