//! Datafit terms `f(β) = F(Xβ)` of Problem (1).
//!
//! The solver is generic over this trait. A datafit owns:
//! - the per-coordinate Lipschitz constants `L_j` of `∇_j f` (Assumption 1),
//! - a **state vector** it maintains across coordinate updates. The state's
//!   semantics are the datafit's choice — `Quadratic` stores the residual
//!   `Xβ − y` (so the inner-loop gradient is a plain dot product),
//!   `Logistic` stores `Xβ`, the dual-SVM datafit stores `Gᵀα`. The solver
//!   only threads it through opaquely, calling [`Datafit::update_state`]
//!   after every accepted coordinate move.
//!
//! This mirrors skglm's `Datafit` protocol (`initialize` /
//! `gradient_scalar` / `value`) adapted to Rust ownership.

pub mod grouped;
pub mod huber;
pub mod logistic;
pub mod multitask;
pub mod poisson;
pub mod probit;
pub mod quadratic;
pub mod svc;

pub use grouped::GroupedQuadratic;
pub use huber::Huber;
pub use logistic::Logistic;
pub use multitask::QuadraticMultiTask;
pub use poisson::Poisson;
pub use probit::Probit;
pub use quadratic::Quadratic;
pub use svc::QuadraticSvc;

use crate::linalg::Design;

/// A smooth datafit `f(β) = F(Xβ)` with coordinate-Lipschitz gradient.
pub trait Datafit: Clone + Send + Sync {
    /// Precompute per-coordinate Lipschitz constants (and anything else)
    /// for this (design, target) pair. Must be called before solving.
    fn init(&mut self, design: &Design, y: &[f64]);

    /// Like [`Datafit::init`], reusing a precomputed Gram diagonal
    /// (`‖X_j‖²` per column) when the implementation can. The default
    /// ignores the hint and calls [`Datafit::init`]; `Quadratic`
    /// overrides it (its Lipschitz constants are exactly `‖X_j‖²/n`), so
    /// the coordinator's per-dataset cache skips the O(nnz) column-norm
    /// recomputation on every job sharing a design.
    fn init_cached(&mut self, design: &Design, y: &[f64], col_sq_norms: Option<&[f64]>) {
        let _ = col_sq_norms;
        self.init(design, y);
    }

    /// Per-coordinate Lipschitz constants `L_j` (length p). Valid after
    /// [`Datafit::init`].
    fn lipschitz(&self) -> &[f64];

    /// Build the solver-maintained state for coefficients `beta`.
    fn init_state(&self, design: &Design, y: &[f64], beta: &[f64]) -> Vec<f64>;

    /// Maintain the state after `beta[j] += delta`.
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]);

    /// Datafit value at the current point.
    fn value(&self, y: &[f64], beta: &[f64], state: &[f64]) -> f64;

    /// `∇_j f(β)` given the current state.
    fn grad_j(&self, design: &Design, y: &[f64], state: &[f64], beta: &[f64], j: usize) -> f64;

    /// Full gradient (the working-set scoring pass). The default computes
    /// per-coordinate gradients, parallelised over column ranges on the
    /// kernel engine; implementations override with a fused pass when one
    /// exists (the residual/score datafits route through `Xᵀr`, which is
    /// itself blocked + parallel, optionally via PJRT at the solver
    /// level).
    fn grad_full(
        &self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        beta: &[f64],
        out: &mut [f64],
    ) {
        use crate::linalg::parallel::{self, KernelPolicy};
        let p = design.ncols();
        assert_eq!(out.len(), p);
        let threads = KernelPolicy::global().threads_for(design.stored_entries());
        let ranges = parallel::even_chunks(p, parallel::chunk_count(threads));
        parallel::par_slices(out, &ranges, threads, |_, cols, sub| {
            for (o, j) in sub.iter_mut().zip(cols) {
                *o = self.grad_j(design, y, state, beta, j);
            }
        });
    }

    /// Human-readable name (reports).
    fn name(&self) -> &'static str;

    /// Whether the state vector is an **affine** function of β (true for
    /// every built-in datafit: residual `Xβ−y`, scores `Xβ`, dual `Gᵀα`).
    /// When true, the inner solver combines state *snapshots* with the
    /// Anderson weights (which sum to 1, preserving the affine offset)
    /// instead of replaying O(|ws|·n) column updates per extrapolation —
    /// a measured ~15% epoch-cost saving on dense problems (EXPERIMENTS.md
    /// §Perf). Override to `false` for a datafit with nonlinear state.
    fn state_is_affine(&self) -> bool {
        true
    }

    /// Global Lipschitz constant of ∇f (for ISTA/FISTA baselines): an
    /// upper bound is fine. Default: Σ_j L_j (loose but safe).
    fn global_lipschitz(&self, _design: &Design) -> f64 {
        self.lipschitz().iter().sum()
    }

    /// Gram-engine opt-in: return `Some(c)` iff this datafit is an exact
    /// residual quadratic, i.e. its state is `s = Xβ − y` maintained by
    /// `s += δ·X_j`, its gradient is `∇_j f = c · X_jᵀ s` and its value is
    /// `(c/2)·‖s‖²`. Under that contract the inner loop's working-set
    /// gradient can be maintained in the Gram domain
    /// ([`crate::solver::gram`]) at O(|ws|) per coordinate. Anything that
    /// deviates (weights, nonlinear links, dual states) must return `None`
    /// — the Gram recursion would silently drift otherwise.
    fn residual_quadratic_scale(&self) -> Option<f64> {
        None
    }

    // ---- raw (per-sample) curvature: the prox-Newton protocol ----------
    //
    // Writing `f(β) = F(Xβ)` with separable `F(s) = Σ_i F_i(s_i)`, the
    // outer prox-Newton solver (`crate::solver::prox_newton`) needs the
    // per-sample derivatives `F_i'` and `F_i''` at the current scores to
    // assemble its working-set quadratic subproblem. Datafits with
    // precomputable coordinate Lipschitz bounds don't need these to run
    // the direct-CD path; datafits with *unbounded* curvature (Poisson)
    // can ONLY run through prox-Newton, which is why the protocol lives
    // on the trait rather than on a separate one — a fit spec picks the
    // solver topology per model (see `coordinator::job::SolverTopology`).

    /// Whether [`Datafit::raw_grad`]/[`Datafit::raw_hessian`] are
    /// implemented (i.e. the prox-Newton solver can drive this datafit).
    fn supports_prox_newton(&self) -> bool {
        false
    }

    /// Per-sample gradient `out[i] = ∂F/∂s_i` at the current state (which
    /// must determine the scores `s = Xβ`). Includes any `1/n` factor so
    /// that `Xᵀ·raw_grad = ∇f(β)`.
    fn raw_grad(&self, y: &[f64], state: &[f64], out: &mut [f64]) {
        let _ = (y, state, out);
        unimplemented!("datafit {:?} does not implement raw_grad (prox-Newton)", self.name());
    }

    /// Per-sample curvature `out[i] = ∂²F/∂s_i²` at the current state,
    /// same normalization as [`Datafit::raw_grad`]. Implementations must
    /// return nonnegative values (clamped away from pathological zeros
    /// where needed — probit does).
    fn raw_hessian(&self, y: &[f64], state: &[f64], out: &mut [f64]) {
        let _ = (y, state, out);
        unimplemented!("datafit {:?} does not implement raw_hessian (prox-Newton)", self.name());
    }
}
