//! Multitask quadratic datafit `f(W) = ‖Y − XW‖²_F / (2n)` for
//! `W ∈ R^{p×T}` — the M/EEG inverse problem loss (paper §3.2, Figure 4).
//!
//! Operated on by the block coordinate-descent solver
//! ([`crate::solver::multitask`]): one "coordinate" is a row `W_{j,:}`,
//! the state is the residual `R = XW − Y` (n × T, column-major by task).

use crate::linalg::Design;

#[derive(Clone, Debug, Default)]
pub struct QuadraticMultiTask {
    lipschitz: Vec<f64>,
    inv_n: f64,
    n_tasks: usize,
}

impl QuadraticMultiTask {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn init(&mut self, design: &Design, n_tasks: usize) {
        let n = design.nrows() as f64;
        self.inv_n = 1.0 / n;
        self.n_tasks = n_tasks;
        self.lipschitz = design.col_sq_norms().iter().map(|s| s / n).collect();
    }

    pub fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// Residual R = XW − Y, stored task-major: `state[t*n + i]`.
    /// `w` is row-major by coefficient row: `w[j*T + t]`.
    pub fn init_state(&self, design: &Design, y: &[f64], w: &[f64]) -> Vec<f64> {
        let n = design.nrows();
        let p = design.ncols();
        let t_count = self.n_tasks;
        assert_eq!(y.len(), n * t_count);
        assert_eq!(w.len(), p * t_count);
        let mut state = vec![0.0; n * t_count];
        let mut beta_t = vec![0.0; p];
        let mut xb = vec![0.0; n];
        for t in 0..t_count {
            for j in 0..p {
                beta_t[j] = w[j * t_count + t];
            }
            design.matvec(&beta_t, &mut xb);
            for i in 0..n {
                state[t * n + i] = xb[i] - y[t * n + i];
            }
        }
        state
    }

    /// After `W_{j,:} += delta` (length T): `R[:, t] += delta_t · X[:, j]`.
    pub fn update_state(&self, design: &Design, j: usize, delta: &[f64], state: &mut [f64]) {
        let n = design.nrows();
        for (t, &d) in delta.iter().enumerate() {
            if d != 0.0 {
                design.col_axpy(j, d, &mut state[t * n..(t + 1) * n]);
            }
        }
    }

    pub fn value(&self, state: &[f64]) -> f64 {
        0.5 * self.inv_n * crate::linalg::sq_nrm2(state)
    }

    /// Gradient block `∇_{j,:} f = X[:,j]ᵀ R / n` into `out` (length T).
    pub fn grad_row(&self, design: &Design, state: &[f64], j: usize, out: &mut [f64]) {
        let n = design.nrows();
        for (t, g) in out.iter_mut().enumerate() {
            *g = self.inv_n * design.col_dot(j, &state[t * n..(t + 1) * n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    fn setup() -> (Design, Vec<f64>, QuadraticMultiTask) {
        let x = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, -1.0], vec![0.0, 1.0]]);
        // Y: 3 samples × 2 tasks, task-major
        let y = vec![1.0, 0.0, -1.0, 0.5, 0.5, 0.0];
        let d: Design = x.into();
        let mut f = QuadraticMultiTask::new();
        f.init(&d, 2);
        (d, y, f)
    }

    #[test]
    fn state_is_residual_per_task() {
        let (d, y, f) = setup();
        // W rows: w[j*T + t]
        let w = vec![1.0, 0.0, 0.0, 1.0]; // W = [[1,0],[0,1]]
        let state = f.init_state(&d, &y, &w);
        // task 0 uses beta = [1, 0] -> Xb = [1,3,0]; residual = Xb - y[:,0]
        assert_eq!(&state[0..3], &[0.0, 3.0, 1.0]);
        // task 1 uses beta = [0, 1] -> Xb = [2,-1,1]
        assert_eq!(&state[3..6], &[1.5, -1.5, 1.0]);
    }

    #[test]
    fn update_matches_rebuild() {
        let (d, y, f) = setup();
        let mut w = vec![0.0; 4];
        let mut state = f.init_state(&d, &y, &w);
        let delta = [0.5, -1.0];
        w[2] += delta[0]; // row j=1, task 0
        w[3] += delta[1];
        f.update_state(&d, 1, &delta, &mut state);
        let fresh = f.init_state(&d, &y, &w);
        for (a, b) in state.iter().zip(fresh.iter()) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn grad_row_matches_finite_differences() {
        let (d, y, f) = setup();
        let w = vec![0.2, -0.1, 0.4, 0.3];
        let state = f.init_state(&d, &y, &w);
        let mut g = vec![0.0; 2];
        f.grad_row(&d, &state, 0, &mut g);
        let eps = 1e-6;
        for t in 0..2 {
            let mut wp = w.clone();
            wp[t] += eps;
            let sp = f.init_state(&d, &y, &wp);
            let mut wm = w.clone();
            wm[t] -= eps;
            let sm = f.init_state(&d, &y, &wm);
            let fd = (f.value(&sp) - f.value(&sm)) / (2.0 * eps);
            assert!((fd - g[t]).abs() < 1e-6, "t={t}");
        }
    }
}
