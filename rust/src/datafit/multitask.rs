//! Multitask quadratic datafit `f(W) = ‖Y − XW‖²_F / (2n)` for
//! `W ∈ R^{p×T}` — the M/EEG inverse problem loss (paper §3.2, Figure 4).
//!
//! Implements [`BlockDatafit`] for the shared block-coordinate engine
//! ([`crate::solver::block_cd`]): one block is a row `W_{j,:}` (the
//! uniform partition `BlockPartition::uniform(p, T)` over the row-major
//! flattened `w[j*T + t]`), the state is the residual `R = XW − Y`
//! (n × T, task-major: `state[t*n + i]`).

use crate::linalg::Design;
use crate::solver::block_cd::BlockDatafit;
use crate::solver::partition::BlockPartition;

#[derive(Clone, Debug, Default)]
pub struct QuadraticMultiTask {
    lipschitz: Vec<f64>,
    inv_n: f64,
    n_tasks: usize,
}

impl QuadraticMultiTask {
    /// A multitask datafit for `n_tasks` response columns.
    pub fn new(n_tasks: usize) -> Self {
        assert!(n_tasks >= 1);
        Self { lipschitz: Vec::new(), inv_n: 0.0, n_tasks }
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// Gradient block `∇_{j,:} f = X[:,j]ᵀ R / n` into `out` (length T).
    pub fn grad_row(&self, design: &Design, state: &[f64], j: usize, out: &mut [f64]) {
        let n = design.nrows();
        for (t, g) in out.iter_mut().enumerate() {
            *g = self.inv_n * design.col_dot(j, &state[t * n..(t + 1) * n]);
        }
    }
}

impl BlockDatafit for QuadraticMultiTask {
    fn init_cached(&mut self, design: &Design, y: &[f64], col_sq_norms: Option<&[f64]>) {
        let n = design.nrows() as f64;
        assert_eq!(y.len(), design.nrows() * self.n_tasks, "y must be task-major n·T");
        self.inv_n = 1.0 / n;
        self.lipschitz = match col_sq_norms {
            Some(sq) => {
                assert_eq!(sq.len(), design.ncols());
                sq.iter().map(|s| s / n).collect()
            }
            None => design.col_sq_norms().iter().map(|s| s / n).collect(),
        };
    }

    fn block_lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// Residual R = XW − Y, stored task-major: `state[t*n + i]`.
    /// `v` is row-major by coefficient row: `v[j*T + t]`.
    fn init_state(&self, design: &Design, y: &[f64], v: &[f64]) -> Vec<f64> {
        let n = design.nrows();
        let p = design.ncols();
        let t_count = self.n_tasks;
        assert_eq!(y.len(), n * t_count);
        assert_eq!(v.len(), p * t_count);
        let mut state = vec![0.0; n * t_count];
        let mut beta_t = vec![0.0; p];
        let mut xb = vec![0.0; n];
        for t in 0..t_count {
            for j in 0..p {
                beta_t[j] = v[j * t_count + t];
            }
            design.matvec(&beta_t, &mut xb);
            for i in 0..n {
                state[t * n + i] = xb[i] - y[t * n + i];
            }
        }
        state
    }

    /// After `W_{j,:} += delta` (length T): `R[:, t] += delta_t · X[:, j]`.
    fn update_state(&self, design: &Design, b: usize, delta: &[f64], state: &mut [f64]) {
        let n = design.nrows();
        for (t, &d) in delta.iter().enumerate() {
            if d != 0.0 {
                design.col_axpy(b, d, &mut state[t * n..(t + 1) * n]);
            }
        }
    }

    fn value(&self, _y: &[f64], _v: &[f64], state: &[f64]) -> f64 {
        0.5 * self.inv_n * crate::linalg::sq_nrm2(state)
    }

    fn grad_block(
        &self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _v: &[f64],
        b: usize,
        out: &mut [f64],
    ) {
        self.grad_row(design, state, b, out);
    }

    /// Fused scoring pass: one kernel-engine `Xᵀ R[:,t]` per task instead
    /// of p·T column dots, scattered into the row-major packed gradient.
    fn grad_all(
        &self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _v: &[f64],
        part: &BlockPartition,
        out: &mut [f64],
    ) {
        let n = design.nrows();
        let p = design.ncols();
        let t_count = self.n_tasks;
        debug_assert_eq!(part.n_blocks(), p);
        debug_assert_eq!(out.len(), p * t_count);
        let mut xtr = vec![0.0; p];
        for t in 0..t_count {
            design.matvec_t(&state[t * n..(t + 1) * n], &mut xtr);
            for j in 0..p {
                out[j * t_count + t] = self.inv_n * xtr[j];
            }
        }
    }

    fn name(&self) -> &'static str {
        "quadratic_multitask"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    fn setup() -> (Design, Vec<f64>, QuadraticMultiTask) {
        let x = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, -1.0], vec![0.0, 1.0]]);
        // Y: 3 samples × 2 tasks, task-major
        let y = vec![1.0, 0.0, -1.0, 0.5, 0.5, 0.0];
        let d: Design = x.into();
        let mut f = QuadraticMultiTask::new(2);
        f.init(&d, &y);
        (d, y, f)
    }

    #[test]
    fn state_is_residual_per_task() {
        let (d, y, f) = setup();
        // W rows: w[j*T + t]
        let w = vec![1.0, 0.0, 0.0, 1.0]; // W = [[1,0],[0,1]]
        let state = f.init_state(&d, &y, &w);
        // task 0 uses beta = [1, 0] -> Xb = [1,3,0]; residual = Xb - y[:,0]
        assert_eq!(&state[0..3], &[0.0, 3.0, 1.0]);
        // task 1 uses beta = [0, 1] -> Xb = [2,-1,1]
        assert_eq!(&state[3..6], &[1.5, -1.5, 1.0]);
    }

    #[test]
    fn update_matches_rebuild() {
        let (d, y, f) = setup();
        let mut w = vec![0.0; 4];
        let mut state = f.init_state(&d, &y, &w);
        let delta = [0.5, -1.0];
        w[2] += delta[0]; // row j=1, task 0
        w[3] += delta[1];
        f.update_state(&d, 1, &delta, &mut state);
        let fresh = f.init_state(&d, &y, &w);
        for (a, b) in state.iter().zip(fresh.iter()) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn grad_row_matches_finite_differences() {
        let (d, y, f) = setup();
        let w = vec![0.2, -0.1, 0.4, 0.3];
        let state = f.init_state(&d, &y, &w);
        let mut g = vec![0.0; 2];
        f.grad_row(&d, &state, 0, &mut g);
        let eps = 1e-6;
        for t in 0..2 {
            let mut wp = w.clone();
            wp[t] += eps;
            let sp = f.init_state(&d, &y, &wp);
            let mut wm = w.clone();
            wm[t] -= eps;
            let sm = f.init_state(&d, &y, &wm);
            let fd = (f.value(&y, &wp, &sp) - f.value(&y, &wm, &sm)) / (2.0 * eps);
            assert!((fd - g[t]).abs() < 1e-6, "t={t}");
        }
    }

    #[test]
    fn fused_grad_all_matches_per_block() {
        let (d, y, f) = setup();
        let part = BlockPartition::uniform(2, 2);
        let w = vec![0.2, -0.1, 0.4, 0.3];
        let state = f.init_state(&d, &y, &w);
        let mut fused = vec![0.0; 4];
        f.grad_all(&d, &y, &state, &w, &part, &mut fused);
        let mut per_block = vec![0.0; 4];
        for b in 0..2 {
            f.grad_block(&d, &y, &state, &w, b, &mut per_block[b * 2..(b + 1) * 2]);
        }
        for (a, b) in fused.iter().zip(per_block.iter()) {
            assert!((a - b).abs() < 1e-14, "{a} vs {b}");
        }
    }
}
