//! Huber datafit `f(β) = (1/n) Σ h_δ(y_i − (Xβ)_i)` — robust regression.
//!
//! Not in the paper's experiments, but exactly the kind of model its
//! modularity claim is about: adding it to the framework is this one file
//! (value + elementwise derivative + Lipschitz), and every solver feature
//! (working sets, Anderson, non-convex penalties) composes with it
//! for free. `h_δ(r) = r²/2` for `|r| ≤ δ`, else `δ|r| − δ²/2`.

use super::Datafit;
use crate::linalg::Design;

#[derive(Clone, Debug)]
pub struct Huber {
    pub delta: f64,
    lipschitz: Vec<f64>,
    inv_n: f64,
}

impl Huber {
    pub fn new(delta: f64) -> Self {
        assert!(delta > 0.0, "Huber delta must be positive");
        Self { delta, lipschitz: Vec::new(), inv_n: 0.0 }
    }
}

/// h'_δ(r): clipped identity.
#[inline]
fn huber_deriv(r: f64, delta: f64) -> f64 {
    r.clamp(-delta, delta)
}

impl Datafit for Huber {
    fn init(&mut self, design: &Design, y: &[f64]) {
        assert_eq!(design.nrows(), y.len());
        let n = design.nrows() as f64;
        self.inv_n = 1.0 / n;
        // |h''| <= 1 elementwise
        self.lipschitz = design.col_sq_norms().iter().map(|s| s / n).collect();
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = Xβ.
    fn init_state(&self, design: &Design, _y: &[f64], beta: &[f64]) -> Vec<f64> {
        let mut xw = vec![0.0; design.nrows()];
        design.matvec(beta, &mut xw);
        xw
    }

    #[inline]
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]) {
        design.col_axpy(j, delta, state);
    }

    fn value(&self, y: &[f64], _beta: &[f64], state: &[f64]) -> f64 {
        let d = self.delta;
        let mut s = 0.0;
        for (&xw, &yi) in state.iter().zip(y.iter()) {
            let r = (yi - xw).abs();
            s += if r <= d { 0.5 * r * r } else { d * r - 0.5 * d * d };
        }
        s * self.inv_n
    }

    #[inline]
    fn grad_j(&self, design: &Design, y: &[f64], state: &[f64], _beta: &[f64], j: usize) -> f64 {
        let d = self.delta;
        let inv_n = self.inv_n;
        design.col_dot_map(j, state, |i, xw_i| -huber_deriv(y[i] - xw_i, d) * inv_n)
    }

    fn grad_full(
        &self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) {
        let w: Vec<f64> = state
            .iter()
            .zip(y.iter())
            .map(|(&xw, &yi)| -huber_deriv(yi - xw, self.delta) * self.inv_n)
            .collect();
        design.matvec_t(&w, out);
    }

    fn name(&self) -> &'static str {
        "huber"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::linalg::DenseMatrix;
    use crate::penalty::L1;
    use crate::solver::{solve, SolverOpts};
    use crate::util::rng::Rng;

    fn setup() -> (Design, Vec<f64>, Huber) {
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![-3.0, 1.0],
            vec![0.5, -1.0],
            vec![2.0, 0.3],
        ]);
        let y = vec![0.5, -4.0, 1.0, 0.1]; // one "outlier"-ish target
        let d: Design = x.into();
        let mut f = Huber::new(1.0);
        f.init(&d, &y);
        (d, y, f)
    }

    #[test]
    fn matches_quadratic_inside_delta() {
        // with a huge delta, Huber == quadratic
        let (d, y, _) = setup();
        let mut h = Huber::new(1e9);
        h.init(&d, &y);
        let mut q = crate::datafit::Quadratic::new();
        q.init(&d, &y);
        let beta = vec![0.1, -0.2];
        let sh = h.init_state(&d, &y, &beta);
        let sq = q.init_state(&d, &y, &beta);
        assert!((h.value(&y, &beta, &sh) - q.value(&y, &beta, &sq)).abs() < 1e-12);
        for j in 0..2 {
            assert!(
                (h.grad_j(&d, &y, &sh, &beta, j) - q.grad_j(&d, &y, &sq, &beta, j)).abs() < 1e-12
            );
        }
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (d, y, f) = setup();
        let beta = vec![0.3, -0.4];
        let state = f.init_state(&d, &y, &beta);
        let eps = 1e-7;
        for j in 0..2 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let sp = f.init_state(&d, &y, &bp);
            let mut bm = beta.clone();
            bm[j] -= eps;
            let sm = f.init_state(&d, &y, &bm);
            let fd = (f.value(&y, &bp, &sp) - f.value(&y, &bm, &sm)) / (2.0 * eps);
            let an = f.grad_j(&d, &y, &state, &beta, j);
            assert!((fd - an).abs() < 1e-6, "j={j}: fd={fd} an={an}");
        }
    }

    #[test]
    fn grad_full_matches_grad_j() {
        let (d, y, f) = setup();
        let beta = vec![0.3, -0.4];
        let state = f.init_state(&d, &y, &beta);
        let mut full = vec![0.0; 2];
        f.grad_full(&d, &y, &state, &beta, &mut full);
        for j in 0..2 {
            assert!((full[j] - f.grad_j(&d, &y, &state, &beta, j)).abs() < 1e-13);
        }
    }

    /// The modularity payoff: Huber + L1 solves through the full skglm
    /// machinery (working sets + Anderson) with zero solver changes, and
    /// is robust to label outliers where the quadratic loss is not.
    #[test]
    fn huber_lasso_is_robust_to_outliers() {
        let ds = correlated(CorrelatedSpec { n: 150, p: 80, rho: 0.3, nnz: 6, snr: 20.0 }, 9);
        let mut y = ds.y.clone();
        // corrupt 5% of targets with huge outliers
        let mut rng = Rng::seed_from_u64(10);
        for _ in 0..8 {
            let i = rng.below(150);
            y[i] += 100.0 * if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        }
        let lam_h = {
            // huber lambda_max is data-dependent; reuse quadratic's as scale
            crate::estimators::linear::quadratic_lambda_max(&ds.design, &y) / 50.0
        };
        let mut huber = Huber::new(1.0);
        let rob = solve(&ds.design, &y, &mut huber, &L1::new(lam_h), &SolverOpts::default().with_tol(1e-8), None, None);
        let mut quad = crate::datafit::Quadratic::new();
        let frag =
            solve(&ds.design, &y, &mut quad, &L1::new(lam_h), &SolverOpts::default().with_tol(1e-8), None, None);
        assert!(rob.converged, "kkt {}", rob.kkt);
        let err_rob = crate::metrics::estimation_error(&rob.beta, &ds.beta_true);
        let err_frag = crate::metrics::estimation_error(&frag.beta, &ds.beta_true);
        assert!(
            err_rob < err_frag,
            "huber ({err_rob:.3}) must beat quadratic ({err_frag:.3}) under outliers"
        );
    }
}
