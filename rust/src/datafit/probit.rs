//! Probit datafit `f(β) = −(1/n) Σ_i log Φ(y_i (Xβ)_i)` with labels
//! y ∈ {−1, +1} — probit regression, the Gaussian-link sibling of
//! logistic regression.
//!
//! Unlike Poisson, the probit curvature is globally bounded by 1 (the
//! inverse-Mills-ratio identity `λ(z)(z + λ(z)) ∈ (0, 1)`), so both the
//! direct-CD solver (with `L_j = ‖X_j‖²/n`) and the prox-Newton solver
//! can drive it — the agreement between the two topologies is one of the
//! GLM integration tests.
//!
//! No `erf` in `std`: [`normal_cdf`] uses the non-alternating Taylor
//! series of `erf` for small arguments and the Laplace continued
//! fraction of `erfc` for the tail — both accurate to ~1e-15, and the
//! continued fraction keeps the inverse Mills ratio `φ(z)/Φ(z)` stable
//! down to z ≈ −37 (beyond which its asymptote `−z` takes over).
//!
//! State = `Xβ`.

use super::Datafit;
use crate::linalg::Design;

#[derive(Clone, Debug, Default)]
pub struct Probit {
    lipschitz: Vec<f64>,
    inv_n: f64,
}

impl Probit {
    pub fn new() -> Self {
        Self::default()
    }
}

const FRAC_1_SQRT_2PI: f64 = 0.398_942_280_401_432_7; // 1/√(2π)

/// Standard normal density φ(z).
#[inline]
pub fn normal_pdf(z: f64) -> f64 {
    FRAC_1_SQRT_2PI * (-0.5 * z * z).exp()
}

/// erf(x) for |x| ≤ 3 via the non-alternating series
/// `erf(x) = (2x/√π) e^{−x²} Σ_{k≥0} (2x²)^k / (2k+1)!!` — all terms
/// positive, no cancellation.
fn erf_series(x: f64) -> f64 {
    let two_x2 = 2.0 * x * x;
    let mut term = 1.0f64;
    let mut sum = 1.0f64;
    let mut k = 1.0f64;
    while term > 1e-18 * sum {
        term *= two_x2 / (2.0 * k + 1.0);
        sum += term;
        k += 1.0;
        if k > 300.0 {
            break;
        }
    }
    2.0 * x * (-x * x).exp() * sum / std::f64::consts::PI.sqrt()
}

/// erfc(x) for x ≥ 3 via the Laplace continued fraction
/// `erfc(x) = e^{−x²}/√π · 1/(x + (1/2)/(x + 1/(x + (3/2)/(x + …))))`.
fn erfc_cf(x: f64) -> f64 {
    // 100 bottom-up levels: comfortably past double-precision convergence
    // at the slowest point of the switch (x ≈ 3)
    let mut f = 0.0f64;
    for k in (1..=100).rev() {
        f = (k as f64 / 2.0) / (x + f);
    }
    (-x * x).exp() / (std::f64::consts::PI.sqrt() * (x + f))
}

/// Standard normal CDF Φ(z), accurate over the whole double range
/// (underflows to 0 below z ≈ −37.5, where [`mills_ratio`] switches to
/// its asymptote).
pub fn normal_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    if x.abs() <= 3.0 {
        0.5 * (1.0 + erf_series(x))
    } else if x > 0.0 {
        1.0 - 0.5 * erfc_cf(x)
    } else {
        0.5 * erfc_cf(-x)
    }
}

/// log Φ(z), finite for all finite z (asymptotic expansion in the far
/// left tail where Φ underflows).
pub fn log_normal_cdf(z: f64) -> f64 {
    if z < -36.0 {
        // log Φ(z) ≈ −z²/2 − log(−z√(2π)) + log(1 − 1/z²)
        -0.5 * z * z - (-z * (2.0 * std::f64::consts::PI).sqrt()).ln() + (-1.0 / (z * z)).ln_1p()
    } else {
        normal_cdf(z).ln()
    }
}

/// Inverse Mills ratio `λ(z) = φ(z)/Φ(z)` — the probit per-sample
/// gradient magnitude. Stable in the far left tail via the asymptote
/// `λ(z) → −z · (1 + 1/z² + …)⁻¹ ≈ −z − 1/z`.
pub fn mills_ratio(z: f64) -> f64 {
    if z < -36.0 {
        -z - 1.0 / z
    } else {
        normal_pdf(z) / normal_cdf(z)
    }
}

impl Datafit for Probit {
    fn init(&mut self, design: &Design, y: &[f64]) {
        assert_eq!(design.nrows(), y.len());
        for &yi in y {
            assert!(yi == 1.0 || yi == -1.0, "probit labels must be ±1, got {yi}");
        }
        let n = design.nrows() as f64;
        self.inv_n = 1.0 / n;
        // curvature λ(z)(z+λ(z)) < 1 globally ⇒ L_j = ‖X_j‖²/n is a valid
        // (if loose) coordinate bound — probit runs on either topology
        self.lipschitz = design.col_sq_norms().iter().map(|s| s / n).collect();
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = Xβ.
    fn init_state(&self, design: &Design, _y: &[f64], beta: &[f64]) -> Vec<f64> {
        let mut xw = vec![0.0; design.nrows()];
        design.matvec(beta, &mut xw);
        xw
    }

    #[inline]
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]) {
        design.col_axpy(j, delta, state);
    }

    fn value(&self, y: &[f64], _beta: &[f64], state: &[f64]) -> f64 {
        let mut s = 0.0;
        for (&xw, &yi) in state.iter().zip(y.iter()) {
            s -= log_normal_cdf(yi * xw);
        }
        s * self.inv_n
    }

    #[inline]
    fn grad_j(&self, design: &Design, y: &[f64], state: &[f64], _beta: &[f64], j: usize) -> f64 {
        let inv_n = self.inv_n;
        design.col_dot_map(j, state, |i, xw_i| -y[i] * mills_ratio(y[i] * xw_i) * inv_n)
    }

    fn grad_full(
        &self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) {
        let mut w = vec![0.0; state.len()];
        self.raw_grad(y, state, &mut w);
        design.matvec_t(&w, out);
    }

    fn name(&self) -> &'static str {
        "probit"
    }

    fn supports_prox_newton(&self) -> bool {
        true
    }

    /// `F_i'(s) = −y_i λ(y_i s)/n`.
    fn raw_grad(&self, y: &[f64], state: &[f64], out: &mut [f64]) {
        for ((o, &xw), &yi) in out.iter_mut().zip(state.iter()).zip(y.iter()) {
            *o = -yi * mills_ratio(yi * xw) * self.inv_n;
        }
    }

    /// `F_i''(s) = λ(z)(z + λ(z))/n` with `z = y_i s` — in `(0, 1/n)`,
    /// clamped away from 0 so the Newton subproblem stays well-posed on
    /// confidently-classified samples.
    fn raw_hessian(&self, y: &[f64], state: &[f64], out: &mut [f64]) {
        for ((o, &xw), &yi) in out.iter_mut().zip(state.iter()).zip(y.iter()) {
            let z = yi * xw;
            let lam = mills_ratio(z);
            *o = (lam * (z + lam)).clamp(1e-10, 1.0) * self.inv_n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    #[test]
    fn normal_cdf_reference_values() {
        // Φ(0) = 0.5, Φ(1.96) ≈ 0.9750021, symmetry, tails
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-15);
        assert!((normal_cdf(1.96) - 0.975_002_104_851_780_4).abs() < 1e-12);
        for &z in &[0.1, 0.7, 1.5, 2.9, 3.3, 5.0, 8.0] {
            assert!((normal_cdf(z) + normal_cdf(-z) - 1.0).abs() < 1e-14, "symmetry at {z}");
        }
        // Φ(−5) ≈ 2.866516e-7 (known value, relative check in the tail)
        let phi5 = normal_cdf(-5.0);
        assert!((phi5 - 2.866_515_718_791_94e-7).abs() / phi5 < 1e-10, "Φ(−5) = {phi5}");
    }

    #[test]
    fn mills_ratio_tail_is_stable_and_monotone() {
        // λ(z) > −z for all z, and λ(z) ≈ −z − 1/z in the far tail
        for &z in &[-50.0, -40.0, -36.5, -35.0, -20.0, -10.0, -5.0, 0.0, 5.0] {
            let l = mills_ratio(z);
            assert!(l.is_finite() && l > 0.0, "λ({z}) = {l}");
            assert!(l > -z - 1e-9, "λ({z}) = {l} below its lower bound");
        }
        // continuity across the asymptote switch at z = −36
        let a = mills_ratio(-36.0 - 1e-9);
        let b = mills_ratio(-36.0 + 1e-9);
        assert!((a - b).abs() / a < 1e-5, "λ discontinuous at switch: {a} vs {b}");
    }

    fn setup() -> (Design, Vec<f64>, Probit) {
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![-3.0, 1.0],
            vec![0.5, -1.0],
            vec![2.0, 0.3],
        ]);
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let d: Design = x.into();
        let mut f = Probit::new();
        f.init(&d, &y);
        (d, y, f)
    }

    #[test]
    fn value_at_zero_is_log2() {
        // −log Φ(0) = log 2 per sample
        let (d, y, f) = setup();
        let beta = vec![0.0, 0.0];
        let state = f.init_state(&d, &y, &beta);
        assert!((f.value(&y, &beta, &state) - (2.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (d, y, f) = setup();
        let beta = vec![0.4, -0.2];
        let state = f.init_state(&d, &y, &beta);
        let eps = 1e-6;
        for j in 0..2 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let sp = f.init_state(&d, &y, &bp);
            let mut bm = beta.clone();
            bm[j] -= eps;
            let sm = f.init_state(&d, &y, &bm);
            let fd = (f.value(&y, &bp, &sp) - f.value(&y, &bm, &sm)) / (2.0 * eps);
            let an = f.grad_j(&d, &y, &state, &beta, j);
            assert!((fd - an).abs() < 1e-6, "j={j}: fd={fd} an={an}");
        }
    }

    #[test]
    fn raw_hessian_matches_grad_finite_differences() {
        let (d, y, f) = setup();
        let beta = vec![0.4, -0.2];
        let state = f.init_state(&d, &y, &beta);
        let eps = 1e-6;
        let mut h = vec![0.0; 4];
        f.raw_hessian(&y, &state, &mut h);
        for i in 0..4 {
            let mut sp = state.clone();
            sp[i] += eps;
            let mut sm = state.clone();
            sm[i] -= eps;
            let mut wp = vec![0.0; 4];
            let mut wm = vec![0.0; 4];
            f.raw_grad(&y, &sp, &mut wp);
            f.raw_grad(&y, &sm, &mut wm);
            let fd = (wp[i] - wm[i]) / (2.0 * eps);
            assert!((fd - h[i]).abs() < 1e-6, "i={i}: fd={fd} an={}", h[i]);
        }
    }

    #[test]
    fn curvature_is_bounded_by_one_over_n() {
        let (d, y, f) = setup();
        // extreme scores in both directions
        let state = vec![30.0, -30.0, 100.0, -100.0];
        let mut h = vec![0.0; 4];
        f.raw_hessian(&y, &state, &mut h);
        for (i, &hi) in h.iter().enumerate() {
            assert!(hi > 0.0 && hi <= 0.25 + 1e-12, "h[{i}] = {hi} out of (0, 1/n]");
        }
        let _ = d;
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_regression_targets() {
        let x = DenseMatrix::from_rows(&[vec![1.0]]);
        let mut f = Probit::new();
        f.init(&x.into(), &[0.5]);
    }
}
