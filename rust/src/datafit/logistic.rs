//! Logistic datafit `f(β) = (1/n) Σ log(1 + exp(−y_i (Xβ)_i))` with
//! labels y ∈ {−1, +1} — sparse logistic regression.
//!
//! State = `Xβ` (the raw scores): each coordinate gradient needs the
//! elementwise sigmoid weights, computed on the fly over the column's
//! stored entries via [`Design::col_dot_map`].

use super::Datafit;
use crate::linalg::Design;

#[derive(Clone, Debug, Default)]
pub struct Logistic {
    lipschitz: Vec<f64>,
    inv_n: f64,
}

impl Logistic {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Numerically stable log(1 + exp(v)).
#[inline]
fn log1p_exp(v: f64) -> f64 {
    if v > 33.0 {
        v
    } else if v > -33.0 {
        v.exp().ln_1p()
    } else {
        0.0
    }
}

/// σ(v) = 1/(1+e^{−v}), stable.
#[inline]
fn sigmoid(v: f64) -> f64 {
    if v >= 0.0 {
        1.0 / (1.0 + (-v).exp())
    } else {
        let e = v.exp();
        e / (1.0 + e)
    }
}

impl Datafit for Logistic {
    fn init(&mut self, design: &Design, y: &[f64]) {
        assert_eq!(design.nrows(), y.len());
        for &yi in y {
            assert!(yi == 1.0 || yi == -1.0, "logistic labels must be ±1, got {yi}");
        }
        let n = design.nrows() as f64;
        self.inv_n = 1.0 / n;
        // |F''| <= 1/4 elementwise -> L_j = ||X_j||² / (4n)
        self.lipschitz = design.col_sq_norms().iter().map(|s| s / (4.0 * n)).collect();
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = Xβ.
    fn init_state(&self, design: &Design, _y: &[f64], beta: &[f64]) -> Vec<f64> {
        let mut xw = vec![0.0; design.nrows()];
        design.matvec(beta, &mut xw);
        xw
    }

    #[inline]
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]) {
        design.col_axpy(j, delta, state);
    }

    fn value(&self, y: &[f64], _beta: &[f64], state: &[f64]) -> f64 {
        let mut s = 0.0;
        for (&xw, &yi) in state.iter().zip(y.iter()) {
            s += log1p_exp(-yi * xw);
        }
        s * self.inv_n
    }

    #[inline]
    fn grad_j(&self, design: &Design, y: &[f64], state: &[f64], _beta: &[f64], j: usize) -> f64 {
        let inv_n = self.inv_n;
        design.col_dot_map(j, state, |i, xw_i| -y[i] * sigmoid(-y[i] * xw_i) * inv_n)
    }

    fn grad_full(
        &self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) {
        // fused pass: materialise the weights once (O(n)), then Xᵀw
        let w: Vec<f64> = state
            .iter()
            .zip(y.iter())
            .map(|(&xw, &yi)| -yi * sigmoid(-yi * xw) * self.inv_n)
            .collect();
        design.matvec_t(&w, out);
    }

    fn name(&self) -> &'static str {
        "logistic"
    }

    fn supports_prox_newton(&self) -> bool {
        true
    }

    /// `F_i(s) = log(1+exp(−y_i s))/n` ⇒ `F_i' = −y_i σ(−y_i s)/n`.
    fn raw_grad(&self, y: &[f64], state: &[f64], out: &mut [f64]) {
        for ((o, &xw), &yi) in out.iter_mut().zip(state.iter()).zip(y.iter()) {
            *o = -yi * sigmoid(-yi * xw) * self.inv_n;
        }
    }

    /// `F_i'' = σ(s)(1−σ(s))/n` (independent of the label sign).
    fn raw_hessian(&self, _y: &[f64], state: &[f64], out: &mut [f64]) {
        for (o, &xw) in out.iter_mut().zip(state.iter()) {
            let s = sigmoid(xw);
            *o = s * (1.0 - s) * self.inv_n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    fn setup() -> (Design, Vec<f64>, Logistic) {
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![-3.0, 1.0],
            vec![0.5, -1.0],
            vec![2.0, 0.3],
        ]);
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let d: Design = x.into();
        let mut f = Logistic::new();
        f.init(&d, &y);
        (d, y, f)
    }

    #[test]
    fn value_at_zero_is_log2() {
        let (d, y, f) = setup();
        let beta = vec![0.0, 0.0];
        let state = f.init_state(&d, &y, &beta);
        assert!((f.value(&y, &beta, &state) - (2.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (d, y, f) = setup();
        let beta = vec![0.4, -0.2];
        let state = f.init_state(&d, &y, &beta);
        let eps = 1e-6;
        for j in 0..2 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let sp = f.init_state(&d, &y, &bp);
            let mut bm = beta.clone();
            bm[j] -= eps;
            let sm = f.init_state(&d, &y, &bm);
            let fd = (f.value(&y, &bp, &sp) - f.value(&y, &bm, &sm)) / (2.0 * eps);
            let an = f.grad_j(&d, &y, &state, &beta, j);
            assert!((fd - an).abs() < 1e-6, "j={j}: fd={fd} an={an}");
        }
    }

    #[test]
    fn grad_full_matches_grad_j() {
        let (d, y, f) = setup();
        let beta = vec![0.4, -0.2];
        let state = f.init_state(&d, &y, &beta);
        let mut full = vec![0.0; 2];
        f.grad_full(&d, &y, &state, &beta, &mut full);
        for j in 0..2 {
            assert!((full[j] - f.grad_j(&d, &y, &state, &beta, j)).abs() < 1e-12);
        }
    }

    #[test]
    fn stable_for_extreme_scores() {
        assert!((log1p_exp(1000.0) - 1000.0).abs() < 1e-9);
        assert_eq!(log1p_exp(-1000.0), 0.0);
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_regression_targets() {
        let x = DenseMatrix::from_rows(&[vec![1.0]]);
        let mut f = Logistic::new();
        f.init(&x.into(), &[0.5]);
    }
}
