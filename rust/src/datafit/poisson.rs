//! Poisson datafit `f(β) = (1/n) Σ_i [exp((Xβ)_i) − y_i (Xβ)_i]` — the
//! negative Poisson log-likelihood with `exp` inverse link (the constant
//! `Σ log y_i!` term is dropped), for count targets `y_i ≥ 0`.
//!
//! The per-sample curvature `exp(s_i)/n` is **unbounded** in β, so no
//! precomputable coordinate Lipschitz constant exists and the direct-CD
//! solver cannot drive this datafit — it is the motivating workload for
//! the prox-Newton outer solver ([`crate::solver::prox_newton`]), which
//! rebuilds the curvature at every outer iteration. The `lipschitz()`
//! values reported here are the *local* bounds at β = 0 (`‖X_j‖²/n`),
//! kept only so diagnostics and λ-grid code paths that expect the field
//! don't break; they are not a valid global majorization.
//!
//! State = `Xβ` (the linear predictor / raw scores).

use super::Datafit;
use crate::linalg::Design;

#[derive(Clone, Debug, Default)]
pub struct Poisson {
    lipschitz: Vec<f64>,
    inv_n: f64,
}

impl Poisson {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Overflow guard on the linear predictor: `exp(700)` is the f64 edge;
/// beyond ~30 the line search has already rejected the step on any sane
/// problem, but a diverging trial must yield a large *finite* objective
/// so the backtracking comparison stays ordered.
#[inline]
fn safe_exp(s: f64) -> f64 {
    s.min(700.0).exp()
}

impl Datafit for Poisson {
    fn init(&mut self, design: &Design, y: &[f64]) {
        assert_eq!(design.nrows(), y.len());
        for &yi in y {
            assert!(
                yi >= 0.0 && yi.fract() == 0.0,
                "poisson targets must be nonnegative counts, got {yi}"
            );
        }
        let n = design.nrows() as f64;
        self.inv_n = 1.0 / n;
        // local curvature at β = 0: exp(0) = 1 ⇒ L_j = ‖X_j‖²/n. NOT a
        // global bound (see module docs) — prox-Newton never uses it.
        self.lipschitz = design.col_sq_norms().iter().map(|s| s / n).collect();
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = Xβ.
    fn init_state(&self, design: &Design, _y: &[f64], beta: &[f64]) -> Vec<f64> {
        let mut xw = vec![0.0; design.nrows()];
        design.matvec(beta, &mut xw);
        xw
    }

    #[inline]
    fn update_state(&self, design: &Design, j: usize, delta: f64, state: &mut [f64]) {
        design.col_axpy(j, delta, state);
    }

    fn value(&self, y: &[f64], _beta: &[f64], state: &[f64]) -> f64 {
        let mut s = 0.0;
        for (&xw, &yi) in state.iter().zip(y.iter()) {
            s += safe_exp(xw) - yi * xw;
        }
        s * self.inv_n
    }

    #[inline]
    fn grad_j(&self, design: &Design, y: &[f64], state: &[f64], _beta: &[f64], j: usize) -> f64 {
        let inv_n = self.inv_n;
        design.col_dot_map(j, state, |i, xw_i| (safe_exp(xw_i) - y[i]) * inv_n)
    }

    fn grad_full(
        &self,
        design: &Design,
        y: &[f64],
        state: &[f64],
        _beta: &[f64],
        out: &mut [f64],
    ) {
        // fused pass: materialise the raw gradient once (O(n)), then Xᵀw
        let mut w = vec![0.0; state.len()];
        self.raw_grad(y, state, &mut w);
        design.matvec_t(&w, out);
    }

    fn name(&self) -> &'static str {
        "poisson"
    }

    fn supports_prox_newton(&self) -> bool {
        true
    }

    /// `F_i'(s) = (exp(s) − y_i)/n`.
    fn raw_grad(&self, y: &[f64], state: &[f64], out: &mut [f64]) {
        for ((o, &xw), &yi) in out.iter_mut().zip(state.iter()).zip(y.iter()) {
            *o = (safe_exp(xw) - yi) * self.inv_n;
        }
    }

    /// `F_i''(s) = exp(s)/n` — the unbounded curvature.
    fn raw_hessian(&self, _y: &[f64], state: &[f64], out: &mut [f64]) {
        for (o, &xw) in out.iter_mut().zip(state.iter()) {
            *o = safe_exp(xw) * self.inv_n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    fn setup() -> (Design, Vec<f64>, Poisson) {
        let x = DenseMatrix::from_rows(&[
            vec![0.5, 1.0],
            vec![-0.8, 0.3],
            vec![0.2, -0.6],
            vec![1.1, 0.4],
        ]);
        let y = vec![2.0, 0.0, 1.0, 3.0];
        let d: Design = x.into();
        let mut f = Poisson::new();
        f.init(&d, &y);
        (d, y, f)
    }

    #[test]
    fn value_at_zero_is_one_minus_mean_times_zero() {
        // f(0) = (1/n) Σ (1 − 0) = 1
        let (d, y, f) = setup();
        let beta = vec![0.0, 0.0];
        let state = f.init_state(&d, &y, &beta);
        assert!((f.value(&y, &beta, &state) - 1.0).abs() < 1e-14);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (d, y, f) = setup();
        let beta = vec![0.3, -0.4];
        let state = f.init_state(&d, &y, &beta);
        let eps = 1e-6;
        for j in 0..2 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let sp = f.init_state(&d, &y, &bp);
            let mut bm = beta.clone();
            bm[j] -= eps;
            let sm = f.init_state(&d, &y, &bm);
            let fd = (f.value(&y, &bp, &sp) - f.value(&y, &bm, &sm)) / (2.0 * eps);
            let an = f.grad_j(&d, &y, &state, &beta, j);
            assert!((fd - an).abs() < 1e-6, "j={j}: fd={fd} an={an}");
        }
    }

    #[test]
    fn raw_grad_assembles_full_gradient() {
        let (d, y, f) = setup();
        let beta = vec![0.3, -0.4];
        let state = f.init_state(&d, &y, &beta);
        let mut w = vec![0.0; 4];
        f.raw_grad(&y, &state, &mut w);
        let mut g = vec![0.0; 2];
        d.matvec_t(&w, &mut g);
        for j in 0..2 {
            let gj = f.grad_j(&d, &y, &state, &beta, j);
            assert!((g[j] - gj).abs() < 1e-12, "j={j}: {} vs {gj}", g[j]);
        }
    }

    #[test]
    fn raw_hessian_matches_grad_finite_differences() {
        let (d, y, f) = setup();
        let beta = vec![0.2, 0.1];
        let state = f.init_state(&d, &y, &beta);
        let eps = 1e-6;
        let mut h = vec![0.0; 4];
        f.raw_hessian(&y, &state, &mut h);
        // F'' at s_i by central differences of raw_grad
        for i in 0..4 {
            let mut sp = state.clone();
            sp[i] += eps;
            let mut sm = state.clone();
            sm[i] -= eps;
            let mut wp = vec![0.0; 4];
            let mut wm = vec![0.0; 4];
            f.raw_grad(&y, &sp, &mut wp);
            f.raw_grad(&y, &sm, &mut wm);
            let fd = (wp[i] - wm[i]) / (2.0 * eps);
            assert!((fd - h[i]).abs() < 1e-6, "i={i}: fd={fd} an={}", h[i]);
        }
    }

    #[test]
    fn diverging_scores_stay_finite() {
        let (d, y, f) = setup();
        let state = vec![800.0, 800.0, 800.0, 800.0];
        let v = f.value(&y, &vec![0.0; 2], &state);
        assert!(v.is_finite(), "overflow guard failed: {v}");
    }

    #[test]
    #[should_panic(expected = "nonnegative counts")]
    fn rejects_negative_targets() {
        let x = DenseMatrix::from_rows(&[vec![1.0]]);
        let mut f = Poisson::new();
        f.init(&x.into(), &[-1.0]);
    }
}
