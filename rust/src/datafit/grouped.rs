//! Grouped quadratic datafit — `f(β) = ‖y − Xβ‖²/(2n)` viewed through a
//! feature-group [`BlockPartition`] for the single-task group-penalty
//! problems (group Lasso / group MCP / group SCAD).
//!
//! The state is the residual `Xβ − y`, exactly as the scalar
//! [`crate::datafit::Quadratic`]; per-**block** Lipschitz bounds use the
//! Frobenius bound `L_b = Σ_{j∈b} ‖X_j‖²/n ≥ ‖X_bᵀX_b‖₂/n` (safe, cheap,
//! and exact for size-1 blocks — the trivial partition reproduces the
//! scalar solver bit-for-bit). The full scoring pass is the fused
//! kernel-engine `Xᵀr` ([`crate::linalg::Design::matvec_t_groups`]).

use crate::linalg::{group_reduce_sq, Design};
use crate::solver::block_cd::BlockDatafit;
use crate::solver::partition::BlockPartition;
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct GroupedQuadratic {
    part: Arc<BlockPartition>,
    lipschitz: Vec<f64>,
    inv_n: f64,
}

impl GroupedQuadratic {
    /// A quadratic datafit over the given feature partition (blocks index
    /// design columns).
    pub fn new(part: Arc<BlockPartition>) -> Self {
        Self { part, lipschitz: Vec::new(), inv_n: 0.0 }
    }

    pub fn partition(&self) -> &Arc<BlockPartition> {
        &self.part
    }
}

impl BlockDatafit for GroupedQuadratic {
    fn init_cached(&mut self, design: &Design, y: &[f64], col_sq_norms: Option<&[f64]>) {
        let n = design.nrows() as f64;
        assert_eq!(y.len(), design.nrows());
        assert_eq!(self.part.dim(), design.ncols(), "partition must cover the columns");
        self.inv_n = 1.0 / n;
        let grouped = match col_sq_norms {
            Some(sq) => {
                assert_eq!(sq.len(), design.ncols());
                group_reduce_sq(sq, self.part.flat_indices(), self.part.offsets())
            }
            None => design.group_sq_norms(self.part.flat_indices(), self.part.offsets()),
        };
        self.lipschitz = grouped.iter().map(|s| s / n).collect();
    }

    fn block_lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// Residual `Xβ − y` — the scalar quadratic convention, so the
    /// gap-safe machinery (`r = −state`) carries over.
    fn init_state(&self, design: &Design, y: &[f64], v: &[f64]) -> Vec<f64> {
        let mut state = vec![0.0; design.nrows()];
        design.matvec(v, &mut state);
        for (s, &yi) in state.iter_mut().zip(y.iter()) {
            *s -= yi;
        }
        state
    }

    fn update_state(&self, design: &Design, b: usize, delta: &[f64], state: &mut [f64]) {
        for (&d, &j) in delta.iter().zip(self.part.coords(b).iter()) {
            if d != 0.0 {
                design.col_axpy(j, d, state);
            }
        }
    }

    fn value(&self, _y: &[f64], _v: &[f64], state: &[f64]) -> f64 {
        0.5 * self.inv_n * crate::linalg::sq_nrm2(state)
    }

    fn grad_block(
        &self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _v: &[f64],
        b: usize,
        out: &mut [f64],
    ) {
        for (g, &j) in out.iter_mut().zip(self.part.coords(b).iter()) {
            *g = self.inv_n * design.col_dot(j, state);
        }
    }

    /// Fused O(n·p) scoring pass on the kernel engine.
    fn grad_all(
        &self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _v: &[f64],
        part: &BlockPartition,
        out: &mut [f64],
    ) {
        // the engine slices the packed output with *its* partition: a
        // mismatched datafit partition would silently pack in the wrong
        // order, so insist they agree (ptr fast path, O(p) slow path —
        // negligible against the O(n·p) kernel below)
        assert!(
            std::ptr::eq(part, self.part.as_ref()) || *part == *self.part,
            "grouped datafit partition differs from the solve partition"
        );
        design.matvec_t_groups(state, self.part.flat_indices(), out);
        for g in out.iter_mut() {
            *g *= self.inv_n;
        }
    }

    fn name(&self) -> &'static str {
        "grouped_quadratic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{correlated, CorrelatedSpec};
    use crate::datafit::{Datafit, Quadratic};

    #[test]
    fn trivial_partition_matches_scalar_quadratic() {
        let ds = correlated(CorrelatedSpec { n: 40, p: 12, rho: 0.4, nnz: 3, snr: 10.0 }, 0);
        let part = Arc::new(BlockPartition::scalar(ds.p()));
        let mut g = GroupedQuadratic::new(Arc::clone(&part));
        g.init(&ds.design, &ds.y);
        let mut q = Quadratic::new();
        q.init(&ds.design, &ds.y);
        for (a, b) in g.block_lipschitz().iter().zip(q.lipschitz().iter()) {
            assert!((a - b).abs() < 1e-14, "{a} vs {b}");
        }
        let beta = vec![0.1; ds.p()];
        let gs = g.init_state(&ds.design, &ds.y, &beta);
        let qs = q.init_state(&ds.design, &ds.y, &beta);
        assert_eq!(gs, qs);
        assert!((g.value(&ds.y, &beta, &gs) - q.value(&ds.y, &beta, &qs)).abs() < 1e-14);
        let mut grad = vec![0.0; ds.p()];
        g.grad_all(&ds.design, &ds.y, &gs, &beta, &part, &mut grad);
        for (j, &gj) in grad.iter().enumerate() {
            let qj = q.grad_j(&ds.design, &ds.y, &qs, &beta, j);
            assert!((gj - qj).abs() < 1e-12, "grad {j}: {gj} vs {qj}");
        }
    }

    #[test]
    fn block_gradient_matches_finite_differences() {
        let ds = correlated(CorrelatedSpec { n: 30, p: 8, rho: 0.3, nnz: 2, snr: 10.0 }, 1);
        let part = Arc::new(BlockPartition::contiguous_equal(8, 3)); // sizes 3,3,2
        let mut g = GroupedQuadratic::new(Arc::clone(&part));
        g.init(&ds.design, &ds.y);
        let v: Vec<f64> = (0..8).map(|k| 0.1 * (k as f64 - 3.0)).collect();
        let state = g.init_state(&ds.design, &ds.y, &v);
        let eps = 1e-6;
        for b in 0..part.n_blocks() {
            let len = part.block_len(b);
            let mut grad = vec![0.0; len];
            g.grad_block(&ds.design, &ds.y, &state, &v, b, &mut grad);
            for (k, &j) in part.coords(b).iter().enumerate() {
                let mut vp = v.clone();
                vp[j] += eps;
                let sp = g.init_state(&ds.design, &ds.y, &vp);
                let mut vm = v.clone();
                vm[j] -= eps;
                let sm = g.init_state(&ds.design, &ds.y, &vm);
                let fd =
                    (g.value(&ds.y, &vp, &sp) - g.value(&ds.y, &vm, &sm)) / (2.0 * eps);
                assert!((fd - grad[k]).abs() < 1e-6, "block {b} coord {j}");
            }
        }
    }

    #[test]
    fn update_state_matches_rebuild_on_scattered_groups() {
        let ds = correlated(CorrelatedSpec { n: 25, p: 6, rho: 0.2, nnz: 2, snr: 10.0 }, 2);
        let part =
            Arc::new(BlockPartition::from_groups(&[vec![4, 0, 2], vec![1, 5, 3]], 6));
        let mut g = GroupedQuadratic::new(Arc::clone(&part));
        g.init(&ds.design, &ds.y);
        let mut v = vec![0.0; 6];
        let mut state = g.init_state(&ds.design, &ds.y, &v);
        let delta = [0.5, -1.0, 0.25];
        for (k, &j) in part.coords(0).iter().enumerate() {
            v[j] += delta[k];
        }
        g.update_state(&ds.design, 0, &delta, &mut state);
        let fresh = g.init_state(&ds.design, &ds.y, &v);
        for (a, b) in state.iter().zip(fresh.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
