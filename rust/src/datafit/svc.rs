//! Dual-SVM datafit (paper §E.4): the hinge-loss SVM dual
//!
//! ```text
//! argmin_{α ∈ R^n}  ½ αᵀQα − Σ_i α_i    s.t.  0 ≤ α_i ≤ C,
//! ```
//!
//! with `Q = G Gᵀ`, `G = diag(y) X`. Writing `f(α) = ½‖Gᵀα‖² − Σα`, this is
//! Problem (1) with penalty `ι_{[0,C]}` per coordinate. The *design* passed
//! to the solver is `Gᵀ` (d × n: one column per dual variable), the state
//! is `v = Gᵀα ∈ R^d`, and `∇_i f = G_i·v − 1 = col_dot(i, v) − 1`.
//!
//! The generalized support (Definition 4) is the set of *free* dual
//! variables `0 < α_i < C` — the working set tracks the non-bound support
//! vectors, exactly the paper's point that gsupp goes beyond sparsity.

use super::Datafit;
use crate::linalg::{CscMatrix, DenseMatrix, Design};

#[derive(Clone, Debug, Default)]
pub struct QuadraticSvc {
    lipschitz: Vec<f64>,
}

impl QuadraticSvc {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the dual design `Gᵀ` (d × n) from a primal dense design
    /// (n × d) and labels y ∈ {−1, 1}.
    pub fn dual_design_dense(x: &DenseMatrix, y: &[f64]) -> Design {
        let (n, d) = (x.nrows(), x.ncols());
        assert_eq!(y.len(), n);
        let mut g_t = DenseMatrix::zeros(d, n);
        for i in 0..n {
            for j in 0..d {
                g_t.set(j, i, y[i] * x.get(i, j));
            }
        }
        g_t.into()
    }

    /// Build the dual design `Gᵀ` from a primal sparse design.
    pub fn dual_design_sparse(x: &CscMatrix, y: &[f64]) -> Design {
        let (n, d) = (x.nrows(), x.ncols());
        assert_eq!(y.len(), n);
        let mut triplets = Vec::with_capacity(x.nnz());
        for j in 0..d {
            let (rows, vals) = x.col(j);
            for (&i, &v) in rows.iter().zip(vals.iter()) {
                // entry (j, i) of Gᵀ = y_i X_{ij}
                triplets.push((j, i as usize, y[i as usize] * v));
            }
        }
        CscMatrix::from_triplets(d, n, &triplets).into()
    }

    /// Recover the primal coefficients `β = Σ_i y_i α_i X_i: = Gᵀα` —
    /// which is exactly the solver state (Eq. 35 of the paper).
    pub fn primal_coef(state: &[f64]) -> Vec<f64> {
        state.to_vec()
    }
}

impl Datafit for QuadraticSvc {
    /// `y` here is unused (the labels are folded into the dual design);
    /// pass anything of length n.
    fn init(&mut self, design: &Design, _y: &[f64]) {
        // L_i = ‖G_i:‖² = squared norm of column i of Gᵀ
        self.lipschitz = design.col_sq_norms();
    }

    fn lipschitz(&self) -> &[f64] {
        &self.lipschitz
    }

    /// State = Gᵀα ∈ R^d.
    fn init_state(&self, design: &Design, _y: &[f64], alpha: &[f64]) -> Vec<f64> {
        let mut v = vec![0.0; design.nrows()];
        design.matvec(alpha, &mut v);
        v
    }

    #[inline]
    fn update_state(&self, design: &Design, i: usize, delta: f64, state: &mut [f64]) {
        design.col_axpy(i, delta, state);
    }

    fn value(&self, _y: &[f64], alpha: &[f64], state: &[f64]) -> f64 {
        0.5 * crate::linalg::sq_nrm2(state) - alpha.iter().sum::<f64>()
    }

    #[inline]
    fn grad_j(&self, design: &Design, _y: &[f64], state: &[f64], _alpha: &[f64], i: usize) -> f64 {
        design.col_dot(i, state) - 1.0
    }

    fn grad_full(
        &self,
        design: &Design,
        _y: &[f64],
        state: &[f64],
        _alpha: &[f64],
        out: &mut [f64],
    ) {
        design.matvec_t(state, out);
        for g in out.iter_mut() {
            *g -= 1.0;
        }
    }

    fn name(&self) -> &'static str {
        "quadratic_svc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (DenseMatrix, Vec<f64>) {
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![-1.0, 0.5],
            vec![2.0, -1.0],
        ]);
        let y = vec![1.0, -1.0, 1.0];
        (x, y)
    }

    #[test]
    fn dual_design_is_yx_transposed() {
        let (x, y) = toy();
        let d = QuadraticSvc::dual_design_dense(&x, &y);
        assert_eq!(d.nrows(), 2); // features
        assert_eq!(d.ncols(), 3); // samples
        // column i of Gᵀ = y_i * X_{i,:}
        assert_eq!(d.col_dot(1, &[1.0, 0.0]), -1.0 * 1.0 * -1.0); // y_1 X_{1,0} = 1
    }

    #[test]
    fn sparse_and_dense_dual_designs_agree() {
        let (x, y) = toy();
        let mut trips = Vec::new();
        for i in 0..3 {
            for j in 0..2 {
                if x.get(i, j) != 0.0 {
                    trips.push((i, j, x.get(i, j)));
                }
            }
        }
        let xs = CscMatrix::from_triplets(3, 2, &trips);
        let dd = QuadraticSvc::dual_design_dense(&x, &y);
        let ds = QuadraticSvc::dual_design_sparse(&xs, &y);
        let alpha = [0.3, 0.7, 0.1];
        let (mut a, mut b) = (vec![0.0; 2], vec![0.0; 2]);
        dd.matvec(&alpha, &mut a);
        ds.matvec(&alpha, &mut b);
        for (u, v) in a.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-14);
        }
    }

    #[test]
    fn value_and_grad_match_quadratic_form() {
        let (x, y) = toy();
        let d = QuadraticSvc::dual_design_dense(&x, &y);
        let mut f = QuadraticSvc::new();
        f.init(&d, &[0.0; 3]);
        let alpha = vec![0.2, 0.5, 0.3];
        let state = f.init_state(&d, &[0.0; 3], &alpha);
        // brute force: Q_{ik} = y_i y_k <X_i, X_k>
        let q = |i: usize, k: usize| {
            y[i] * y[k] * (x.get(i, 0) * x.get(k, 0) + x.get(i, 1) * x.get(k, 1))
        };
        let mut quad = 0.0;
        for i in 0..3 {
            for k in 0..3 {
                quad += alpha[i] * alpha[k] * q(i, k);
            }
        }
        let expect = 0.5 * quad - alpha.iter().sum::<f64>();
        assert!((f.value(&[0.0; 3], &alpha, &state) - expect).abs() < 1e-12);
        for i in 0..3 {
            let gi: f64 = (0..3).map(|k| q(i, k) * alpha[k]).sum::<f64>() - 1.0;
            assert!((f.grad_j(&d, &[0.0; 3], &state, &alpha, i) - gi).abs() < 1e-12);
        }
    }

    #[test]
    fn grad_full_matches_grad_j() {
        let (x, y) = toy();
        let d = QuadraticSvc::dual_design_dense(&x, &y);
        let mut f = QuadraticSvc::new();
        f.init(&d, &[0.0; 3]);
        let alpha = vec![0.1, 0.9, 0.4];
        let state = f.init_state(&d, &[0.0; 3], &alpha);
        let mut full = vec![0.0; 3];
        f.grad_full(&d, &[0.0; 3], &state, &alpha, &mut full);
        for i in 0..3 {
            assert!((full[i] - f.grad_j(&d, &[0.0; 3], &state, &alpha, i)).abs() < 1e-13);
        }
    }
}
