//! Kernel engine: a persistent worker pool + thread-budget policy for the
//! O(n·p) column passes (`Xᵀr` scoring, screening, Gram/norm precompute).
//!
//! Design (ISSUE 2 tentpole):
//! - **Persistent, lazily-spawned pool.** The scoring pass runs every
//!   outer iteration, so per-call thread spawning is unaffordable. Workers
//!   are spawned once on first parallel use and then block on a shared
//!   job queue; a job is a `Fn(task_index)` closure executed over
//!   `0..n_tasks` with dynamic (atomic counter) task claiming. The
//!   submitting thread always participates, so the pool can never
//!   deadlock a caller.
//! - **Column-range tasks.** Consumers split their column space into
//!   contiguous ranges — [`even_chunks`] (dense, panel-aligned via
//!   [`even_chunks_aligned`]) or [`balanced_chunks`] (CSC, nnz-balanced so
//!   a few dense columns don't serialise the pass) — and each task writes
//!   a disjoint slice of the output ([`par_slices`]).
//! - **[`KernelPolicy`]**: serial below [`SERIAL_WORK_THRESHOLD`] stored
//!   entries (small problems lose more to dispatch than they gain), and a
//!   global thread budget shared with the coordinator's solver workers:
//!   when the scheduler runs W concurrent jobs, each job's kernels get
//!   `budget / W` threads so kernel × worker parallelism never
//!   oversubscribes the machine.
//!
//! The budget resolves, in priority order: [`set_thread_budget`] (the CLI
//! `--threads` knob) > the `SKGLM_THREADS` env var > hardware parallelism.
//!
//! Float semantics: every output element is computed by exactly one task
//! with a summation order that depends only on the matrix shape (panel
//! boundaries are alignment-fixed), so results are independent of the
//! thread count.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// ------------------------------------------------------- thread budget --

/// Resolved global thread budget; 0 = not yet resolved.
static BUDGET: AtomicUsize = AtomicUsize::new(0);
/// Solver worker threads currently registered by the fit scheduler.
static SOLVER_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// The global thread budget (`--threads` > `SKGLM_THREADS` > hardware).
pub fn thread_budget() -> usize {
    let b = BUDGET.load(Ordering::Relaxed);
    if b != 0 {
        return b;
    }
    let resolved = env_thread_budget().unwrap_or_else(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    });
    // Racy relaxed first-resolution is fine: every racer computes the
    // same value, and an interleaved `set_thread_budget` wins either way.
    let _ = BUDGET.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed);
    BUDGET.load(Ordering::Relaxed).max(1)
}

/// The `SKGLM_THREADS` override, if set to a positive integer.
pub fn env_thread_budget() -> Option<usize> {
    std::env::var("SKGLM_THREADS").ok().and_then(|v| v.parse::<usize>().ok()).filter(|&n| n >= 1)
}

/// Set the global thread budget (CLI `--threads`). Takes effect for every
/// subsequent policy decision; the worker pool itself is sized once at
/// first parallel use.
pub fn set_thread_budget(n: usize) {
    BUDGET.store(n.max(1), Ordering::Relaxed);
}

/// RAII registration of `n` concurrent solver workers against the kernel
/// budget (held by the coordinator's [`crate::coordinator::FitScheduler`]).
/// While registered, kernel calls get `budget / n` threads each.
pub struct SolverWorkersGuard {
    n: usize,
}

/// Register `n` solver worker threads; the guard releases them on drop.
pub fn register_solver_workers(n: usize) -> SolverWorkersGuard {
    // relaxed is sound: the count only scales per-kernel thread fan-out,
    // an advisory policy input — any momentarily stale read still yields
    // a valid thread split
    SOLVER_WORKERS.fetch_add(n, Ordering::Relaxed);
    SolverWorkersGuard { n }
}

/// Currently registered solver workers (0 when no scheduler is running).
pub fn solver_workers() -> usize {
    SOLVER_WORKERS.load(Ordering::Relaxed)
}

impl Drop for SolverWorkersGuard {
    fn drop(&mut self) {
        // relaxed: same advisory-counter argument as register_solver_workers
        SOLVER_WORKERS.fetch_sub(self.n, Ordering::Relaxed);
    }
}

// -------------------------------------------------------------- policy --

/// Below this many stored entries a kernel runs serially: pool dispatch
/// costs a few µs, which dominates passes smaller than ~a L2 cache.
pub const SERIAL_WORK_THRESHOLD: usize = 1 << 15;

/// Decides how many threads a kernel invocation gets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelPolicy {
    /// Threads available to this kernel call.
    pub threads: usize,
    /// Work (stored entries) below which the call stays serial.
    pub serial_threshold: usize,
}

/// Per-job kernel threads on a `budget` shared by `jobs` concurrent
/// solver workers: `budget / jobs`, floored at 1. Guarantees
/// `kernel threads × jobs ≤ budget` whenever `jobs ≤ budget`.
pub fn divide_budget(budget: usize, jobs: usize) -> usize {
    (budget / jobs.max(1)).max(1)
}

impl KernelPolicy {
    /// The process-wide policy: the thread budget divided by the number of
    /// concurrently registered solver workers (no oversubscription when
    /// `serve`/`path` fan out jobs).
    pub fn global() -> Self {
        Self {
            threads: divide_budget(thread_budget(), solver_workers()),
            serial_threshold: SERIAL_WORK_THRESHOLD,
        }
    }

    /// A policy with an explicit thread count (benches, tests).
    pub fn with_threads(threads: usize) -> Self {
        Self { threads: threads.max(1), serial_threshold: SERIAL_WORK_THRESHOLD }
    }

    /// Threads to use for a pass over `work` stored entries.
    pub fn threads_for(&self, work: usize) -> usize {
        if self.threads <= 1 || work < self.serial_threshold {
            1
        } else {
            self.threads
        }
    }
}

// ------------------------------------------------------------ chunking --

/// Tasks per parallel call: a few per thread so a slow chunk (NUMA, page
/// faults, skewed columns) is absorbed by dynamic claiming.
pub fn chunk_count(threads: usize) -> usize {
    if threads <= 1 {
        1
    } else {
        threads * 4
    }
}

/// Split `0..n` into at most `chunks` contiguous, near-equal ranges.
pub fn even_chunks(n: usize, chunks: usize) -> Vec<Range<usize>> {
    even_chunks_aligned(n, chunks, 1)
}

/// Like [`even_chunks`], but every boundary (except `n` itself) is a
/// multiple of `align`. Dense `Xᵀr` uses `align = PANEL` so panel
/// membership of a column — and hence its summation order — depends only
/// on the matrix shape, never on the thread count.
pub fn even_chunks_aligned(n: usize, chunks: usize, align: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let align = align.max(1);
    let chunks = chunks.clamp(1, n);
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0usize;
    for k in 1..=chunks {
        let end = if k == chunks { n } else { (n * k / chunks / align * align).min(n) };
        if end > start {
            out.push(start..end);
            start = end;
        }
    }
    out
}

/// nnz-balanced column ranges: `cum` is a non-decreasing cumulative-weight
/// array of length `p + 1` (CSC `indptr`); returns at most `chunks`
/// contiguous ranges of `0..p` with roughly equal total weight, so a few
/// dense columns don't serialise a sparse pass.
pub fn balanced_chunks(cum: &[usize], chunks: usize) -> Vec<Range<usize>> {
    let p = cum.len().saturating_sub(1);
    if p == 0 {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, p);
    let total = cum[p] - cum[0];
    if total == 0 {
        return even_chunks(p, chunks);
    }
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0usize;
    for k in 1..=chunks {
        if start >= p {
            break;
        }
        let end = if k == chunks {
            p
        } else {
            let target = cum[0] + total * k / chunks;
            let bound = match cum.binary_search(&target) {
                Ok(i) => i,
                Err(i) => i,
            };
            bound.clamp(start + 1, p)
        };
        out.push(start..end);
        start = end;
    }
    out
}

// ---------------------------------------------------------------- pool --

type Task = dyn Fn(usize) + Sync;

/// One in-flight parallel call. `task` is a lifetime-erased pointer to the
/// caller's closure; soundness rests on `run_tasks` not returning until
/// every helper has finished (the `remaining`/`done` handshake below).
struct Job {
    task: *const Task,
    next: AtomicUsize,
    n_tasks: usize,
    panicked: AtomicBool,
    /// Helpers that have not yet finished this job.
    remaining: Mutex<usize>,
    done: Condvar,
}

// SAFETY: `task` points at a `Sync` closure that outlives the job (the
// submitter blocks until `remaining == 0`); other fields are thread-safe.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct PoolShared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    available: Condvar,
    workers: usize,
}

fn execute(job: &Job) {
    // SAFETY: the submitting thread keeps the closure alive until the
    // completion handshake; see `Job`.
    let task = unsafe { &*job.task };
    loop {
        // relaxed claim counter: indices only partition work; results are
        // published to the submitter by the completion handshake's mutex
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_tasks {
            break;
        }
        IN_KERNEL_TASK.with(|c| c.set(true));
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i)));
        IN_KERNEL_TASK.with(|c| c.set(false));
        if outcome.is_err() {
            // relaxed flag store: the submitter reads the flag only after
            // the completion handshake's Mutex/Condvar has synchronised
            job.panicked.store(true, Ordering::Relaxed);
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        execute(&job);
        let mut left = job.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            job.done.notify_all();
        }
    }
}

fn pool() -> &'static Arc<PoolShared> {
    static POOL: OnceLock<Arc<PoolShared>> = OnceLock::new();
    POOL.get_or_init(|| {
        // Size once: enough helpers for the largest budget we may see.
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let workers = thread_budget().max(hw).saturating_sub(1).clamp(1, 64);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            workers,
        });
        for _ in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("skglm-kernel".to_string())
                .spawn(move || worker_loop(sh))
                .expect("spawning kernel worker");
        }
        shared
    })
}

std::thread_local! {
    /// Set while this thread executes a kernel task: nested parallel calls
    /// degrade to serial instead of waiting on a queue they occupy.
    static IN_KERNEL_TASK: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// Run `f(i)` for every `i in 0..n_tasks` on up to `threads` threads
/// (the calling thread participates; `threads - 1` pool workers help).
/// Returns after **all** tasks completed. Panics in tasks are surfaced as
/// a panic here. `threads <= 1` runs inline with zero dispatch cost.
pub fn run_tasks<F: Fn(usize) + Sync>(threads: usize, n_tasks: usize, f: F) {
    if n_tasks == 0 {
        return;
    }
    let nested = IN_KERNEL_TASK.with(|c| c.get());
    let threads = threads.max(1).min(n_tasks);
    if threads == 1 || nested {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let shared = pool();
    let helpers = (threads - 1).min(shared.workers);
    if helpers == 0 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }

    let task_ref: &(dyn Fn(usize) + Sync) = &f;
    // SAFETY: erase the borrow's lifetime; `task` is only dereferenced
    // while this frame is blocked in the completion wait below.
    let task: *const Task = unsafe { std::mem::transmute(task_ref) };
    let job = Arc::new(Job {
        task,
        next: AtomicUsize::new(0),
        n_tasks,
        panicked: AtomicBool::new(false),
        remaining: Mutex::new(helpers),
        done: Condvar::new(),
    });
    {
        let mut q = shared.queue.lock().unwrap();
        for _ in 0..helpers {
            q.push_back(Arc::clone(&job));
        }
    }
    shared.available.notify_all();

    execute(&job);

    let mut left = job.remaining.lock().unwrap();
    while *left > 0 {
        left = job.done.wait(left).unwrap();
    }
    drop(left);
    if job.panicked.load(Ordering::Relaxed) {
        panic!("a kernel-engine task panicked");
    }
}

/// Raw-pointer wrapper so disjoint output sub-slices can cross threads.
struct SendMutPtr<T>(*mut T);
// SAFETY: only used to rebuild disjoint sub-slices (validated by
// `par_slices`), each touched by exactly one task.
unsafe impl<T: Send> Send for SendMutPtr<T> {}
unsafe impl<T: Send> Sync for SendMutPtr<T> {}

/// Run `f(chunk_index, range, &mut out[range])` for every range, in
/// parallel on up to `threads` threads. `ranges` must be ascending,
/// pairwise disjoint and within `out` (checked).
pub fn par_slices<T, F>(out: &mut [T], ranges: &[Range<usize>], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, Range<usize>, &mut [T]) + Sync,
{
    let mut prev_end = 0usize;
    for r in ranges {
        assert!(
            r.start >= prev_end && r.start <= r.end && r.end <= out.len(),
            "par_slices: ranges must be ascending, disjoint and in bounds"
        );
        prev_end = r.end;
    }
    let base = SendMutPtr(out.as_mut_ptr());
    run_tasks(threads, ranges.len(), |k| {
        let r = ranges[k].clone();
        // SAFETY: ranges are validated disjoint above, so every task gets
        // exclusive access to its sub-slice.
        let sub = unsafe { std::slice::from_raw_parts_mut(base.0.add(r.start), r.end - r.start) };
        f(k, r, sub);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_chunks_cover_exactly() {
        for n in [0usize, 1, 2, 7, 8, 9, 100, 101] {
            for chunks in [1usize, 2, 3, 8, 200] {
                let rs = even_chunks(n, chunks);
                let mut pos = 0;
                for r in &rs {
                    assert_eq!(r.start, pos, "gap at {pos} (n={n}, chunks={chunks})");
                    assert!(r.end > r.start);
                    pos = r.end;
                }
                assert_eq!(pos, n, "n={n}, chunks={chunks}");
            }
        }
    }

    #[test]
    fn aligned_chunks_have_aligned_boundaries() {
        for n in [5usize, 8, 17, 64, 100] {
            for chunks in [2usize, 3, 7] {
                let rs = even_chunks_aligned(n, chunks, 8);
                let mut pos = 0;
                for r in &rs {
                    assert_eq!(r.start, pos);
                    assert!(r.start % 8 == 0, "unaligned start {}", r.start);
                    pos = r.end;
                }
                assert_eq!(pos, n);
            }
        }
    }

    #[test]
    fn balanced_chunks_cover_and_balance() {
        // skewed "indptr": one huge column among many small ones
        let mut cum = vec![0usize];
        for j in 0..40 {
            let w = if j == 3 { 1000 } else { 10 };
            cum.push(cum.last().unwrap() + w);
        }
        let rs = balanced_chunks(&cum, 4);
        let mut pos = 0;
        for r in &rs {
            assert_eq!(r.start, pos);
            assert!(r.end > r.start);
            pos = r.end;
        }
        assert_eq!(pos, 40);
        // the heavy column's chunk should not also carry most small ones:
        // every chunk except the heavy one stays light
        let total = *cum.last().unwrap();
        for r in &rs {
            let w = cum[r.end] - cum[r.start];
            assert!(
                w <= 1000 + total / 2,
                "chunk {r:?} weight {w} badly balanced"
            );
        }
    }

    #[test]
    fn balanced_chunks_all_empty_columns() {
        let cum = vec![0usize; 11]; // 10 empty columns
        let rs = balanced_chunks(&cum, 3);
        let covered: usize = rs.iter().map(|r| r.end - r.start).sum();
        assert_eq!(covered, 10);
    }

    #[test]
    fn run_tasks_visits_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        run_tasks(4, hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn run_tasks_serial_path() {
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        run_tasks(1, 5, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_calls_degrade_to_serial_and_complete() {
        let total = AtomicUsize::new(0);
        run_tasks(4, 8, |_| {
            run_tasks(4, 8, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn par_slices_writes_disjoint_ranges() {
        let mut out = vec![0usize; 100];
        let ranges = even_chunks(100, 7);
        par_slices(&mut out, &ranges, 4, |_, r, sub| {
            for (o, i) in sub.iter_mut().zip(r) {
                *o = i + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i + 1);
        }
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn par_slices_rejects_overlap() {
        let mut out = vec![0.0f64; 10];
        par_slices(&mut out, &[0..6, 5..10], 2, |_, _, _| {});
    }

    #[test]
    fn task_panic_propagates() {
        let res = std::panic::catch_unwind(|| {
            run_tasks(4, 16, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        });
        assert!(res.is_err(), "panic in a task must surface to the caller");
    }

    #[test]
    fn policy_serial_below_threshold() {
        let p = KernelPolicy { threads: 8, serial_threshold: 1000 };
        assert_eq!(p.threads_for(999), 1);
        assert_eq!(p.threads_for(1000), 8);
        let s = KernelPolicy::with_threads(1);
        assert_eq!(s.threads_for(usize::MAX), 1);
    }

    #[test]
    fn budget_division_never_oversubscribes() {
        // pure math (the globals it feeds from are exercised end-to-end in
        // tests/integration_kernels.rs, which owns the process globals)
        assert_eq!(divide_budget(8, 4), 2);
        assert_eq!(divide_budget(8, 6), 1);
        assert_eq!(divide_budget(8, 0), 8, "no registered workers = whole budget");
        assert_eq!(divide_budget(1, 5), 1);
        for budget in 1..=16usize {
            for jobs in 1..=16usize {
                let t = divide_budget(budget, jobs);
                assert!(t >= 1);
                if jobs <= budget {
                    assert!(t * jobs <= budget, "oversubscribed: {t}×{jobs} > {budget}");
                }
            }
        }
    }
}
