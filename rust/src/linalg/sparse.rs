//! Compressed Sparse Column matrix — the huge-scale substrate.
//!
//! The paper's large benchmarks (rcv1, news20, finance, kdda, url) are
//! sparse designs with densities 1e-6..4e-3; coordinate descent on them
//! lives or dies on fast `X[:, j]ᵀ r` and `r += c · X[:, j]` over the
//! column's nonzeros, which CSC gives directly. Built from COO triplets
//! (the libsvm parser emits row-wise entries).

/// CSC sparse matrix, `n` rows × `p` columns.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n: usize,
    p: usize,
    /// Column pointers, length p + 1, non-decreasing, `indptr[p] == nnz`.
    indptr: Vec<usize>,
    /// Row indices per column, strictly increasing within each column.
    indices: Vec<u32>,
    /// Nonzero values, parallel to `indices`.
    data: Vec<f64>,
}

impl CscMatrix {
    /// Build from COO triplets `(row, col, value)`. Duplicate entries are
    /// summed; entries that sum to exactly zero are kept (harmless).
    pub fn from_triplets(n: usize, p: usize, triplets: &[(usize, usize, f64)]) -> Self {
        for &(i, j, _) in triplets {
            assert!(i < n && j < p, "triplet ({i},{j}) out of bounds ({n}x{p})");
        }
        // counting sort by column, then by row within column
        let mut per_col = vec![0usize; p + 1];
        for &(_, j, _) in triplets {
            per_col[j + 1] += 1;
        }
        for j in 0..p {
            per_col[j + 1] += per_col[j];
        }
        let mut order: Vec<usize> = (0..triplets.len()).collect();
        order.sort_by_key(|&k| (triplets[k].1, triplets[k].0));

        let mut indptr = vec![0usize; p + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(triplets.len());
        let mut data: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut cur_col = 0usize;
        for &k in &order {
            let (i, j, v) = triplets[k];
            while cur_col < j {
                cur_col += 1;
                indptr[cur_col] = indices.len();
            }
            if let (Some(&last_i), true) = (indices.last(), indptr[cur_col] < indices.len()) {
                if last_i as usize == i {
                    *data.last_mut().unwrap() += v; // duplicate: accumulate
                    continue;
                }
            }
            indices.push(i as u32);
            data.push(v);
        }
        while cur_col < p {
            cur_col += 1;
            indptr[cur_col] = indices.len();
        }
        Self { n, p, indptr, indices, data }
    }

    /// Build directly from CSC arrays (validated).
    pub fn from_csc(
        n: usize,
        p: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f64>,
    ) -> Self {
        assert_eq!(indptr.len(), p + 1);
        assert_eq!(indices.len(), data.len());
        assert_eq!(*indptr.last().unwrap(), indices.len());
        for j in 0..p {
            assert!(indptr[j] <= indptr[j + 1], "indptr not monotone at col {j}");
            for k in indptr[j]..indptr[j + 1] {
                assert!((indices[k] as usize) < n, "row index out of range");
                if k > indptr[j] {
                    assert!(indices[k - 1] < indices[k], "rows not strictly increasing in col {j}");
                }
            }
        }
        Self { n, p, indptr, indices, data }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n as f64 * self.p as f64)
    }

    /// Nonzeros of column `j` as `(row_indices, values)`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[a..b], &self.data[a..b])
    }

    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }

    /// Sparse dot: `X[:, j]ᵀ r`.
    #[inline]
    pub fn col_dot(&self, j: usize, r: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        let mut s = 0.0;
        for (&i, &v) in rows.iter().zip(vals.iter()) {
            s += v * r[i as usize];
        }
        s
    }

    /// Sparse axpy: `r += c · X[:, j]`.
    #[inline]
    pub fn col_axpy(&self, j: usize, c: f64, r: &mut [f64]) {
        let (rows, vals) = self.col(j);
        for (&i, &v) in rows.iter().zip(vals.iter()) {
            r[i as usize] += c * v;
        }
    }

    /// Column-pair dot `X[:, a]ᵀ X[:, b]` by merge join over the sorted
    /// row indices — the CSC Gram-assembly kernel for short slot lists
    /// (cost `nnz(a) + nnz(b)`, no densification).
    #[inline]
    pub fn col_pair_dot(&self, a: usize, b: usize) -> f64 {
        let (ra, va) = self.col(a);
        let (rb, vb) = self.col(b);
        let (mut i, mut k) = (0usize, 0usize);
        let mut s = 0.0;
        while i < ra.len() && k < rb.len() {
            match ra[i].cmp(&rb[k]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => k += 1,
                std::cmp::Ordering::Equal => {
                    s += va[i] * vb[k];
                    i += 1;
                    k += 1;
                }
            }
        }
        s
    }

    /// `X β` into `out`.
    pub fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for j in 0..self.p {
            let b = beta[j];
            if b != 0.0 {
                self.col_axpy(j, b, out);
            }
        }
    }

    /// `Xᵀ r` into `out`.
    pub fn matvec_t(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for j in 0..self.p {
            out[j] = self.col_dot(j, r);
        }
    }

    /// `Xᵀ r` restricted to the column range `cols`: writes
    /// `out[k] = X[:, cols.start + k]ᵀ r`. The kernel engine calls this on
    /// nnz-balanced ranges ([`crate::linalg::parallel::balanced_chunks`]).
    pub fn matvec_t_range(&self, r: &[f64], cols: std::ops::Range<usize>, out: &mut [f64]) {
        assert!(cols.end <= self.p);
        assert_eq!(out.len(), cols.end - cols.start);
        for (o, j) in out.iter_mut().zip(cols) {
            *o = self.col_dot(j, r);
        }
    }

    /// Multi-RHS `Xᵀ R` over the column range `cols`: `R` is a residual
    /// panel of `n_rhs` column-major vectors (`R[:, c] = r[c·n ..
    /// (c+1)·n]`) and the output is feature-major
    /// (`out[(j − cols.start)·n_rhs + c] = X[:, j]ᵀ R[:, c]`) — the CSC
    /// side of the batched-fit scoring kernel. Each stored `(i, v)` is
    /// loaded once and applied to all `n_rhs` panel columns.
    ///
    /// Bitwise contract: for every `(j, c)` the nonzeros accumulate in
    /// ascending row order into a single accumulator, exactly as
    /// [`CscMatrix::col_dot`] does, so batched scoring matches
    /// single-fit scoring bit-for-bit regardless of the nnz-balanced
    /// thread split.
    pub fn matmul_t_range(
        &self,
        r: &[f64],
        n_rhs: usize,
        cols: std::ops::Range<usize>,
        out: &mut [f64],
    ) {
        assert_eq!(r.len(), self.n * n_rhs);
        assert!(cols.end <= self.p);
        assert_eq!(out.len(), (cols.end - cols.start) * n_rhs);
        if n_rhs == 1 {
            return self.matvec_t_range(r, cols, out);
        }
        let n = self.n;
        for (idx, j) in cols.clone().enumerate() {
            let (rows, vals) = self.col(j);
            let o = &mut out[idx * n_rhs..(idx + 1) * n_rhs];
            o.fill(0.0);
            for (&i, &v) in rows.iter().zip(vals.iter()) {
                let i = i as usize;
                for (c, oc) in o.iter_mut().enumerate() {
                    *oc += v * r[c * n + i];
                }
            }
        }
    }

    /// Column pointers (nnz-balanced chunking in the kernel engine).
    #[inline]
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Scale every column `j` by `scales[j]`, parallelised over the
    /// kernel pool on nnz-balanced column ranges.
    pub fn scale_cols(&mut self, scales: &[f64], threads: usize) {
        assert_eq!(scales.len(), self.p);
        if self.p == 0 || self.data.is_empty() {
            return;
        }
        let col_ranges = super::parallel::balanced_chunks(
            &self.indptr,
            super::parallel::chunk_count(threads),
        );
        let data_ranges: Vec<std::ops::Range<usize>> =
            col_ranges.iter().map(|r| self.indptr[r.start]..self.indptr[r.end]).collect();
        let indptr = &self.indptr;
        super::parallel::par_slices(&mut self.data, &data_ranges, threads, |k, dr, sub| {
            for j in col_ranges[k].clone() {
                let s = scales[j];
                if s != 1.0 {
                    let (a, b) = (indptr[j] - dr.start, indptr[j + 1] - dr.start);
                    for v in &mut sub[a..b] {
                        *v *= s;
                    }
                }
            }
        });
    }

    /// Squared ℓ2 norms of all columns.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.p)
            .map(|j| {
                let (_, vals) = self.col(j);
                vals.iter().map(|v| v * v).sum()
            })
            .collect()
    }

    /// Scale column j in place (used for √n column normalisation).
    pub fn scale_col(&mut self, j: usize, s: f64) {
        for k in self.indptr[j]..self.indptr[j + 1] {
            self.data[k] *= s;
        }
    }

    /// Dense copy (tests / tiny problems only).
    pub fn to_dense(&self) -> super::dense::DenseMatrix {
        let mut m = super::dense::DenseMatrix::zeros(self.n, self.p);
        for j in 0..self.p {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals.iter()) {
                m.set(i as usize, j, v);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CscMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        CscMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 1.0), (2, 0, 4.0), (1, 1, 3.0), (0, 2, 2.0), (2, 2, 5.0)],
        )
    }

    #[test]
    fn triplets_build_correct_csc() {
        let m = small();
        assert_eq!(m.nnz(), 5);
        let (rows, vals) = m.col(0);
        assert_eq!(rows, &[0, 2]);
        assert_eq!(vals, &[1.0, 4.0]);
        let (rows, vals) = m.col(2);
        assert_eq!(rows, &[0, 2]);
        assert_eq!(vals, &[2.0, 5.0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let m = CscMatrix::from_triplets(2, 1, &[(0, 0, 1.0), (0, 0, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.col(0).1, &[3.5]);
    }

    #[test]
    fn empty_columns_ok() {
        let m = CscMatrix::from_triplets(2, 4, &[(1, 2, 7.0)]);
        assert_eq!(m.col_nnz(0), 0);
        assert_eq!(m.col_nnz(2), 1);
        assert_eq!(m.col_nnz(3), 0);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = small();
        let d = m.to_dense();
        let beta = [1.0, -2.0, 0.5];
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        m.matvec(&beta, &mut a);
        d.matvec(&beta, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn matvec_t_matches_dense() {
        let m = small();
        let d = m.to_dense();
        let r = [1.0, 2.0, 3.0];
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        m.matvec_t(&r, &mut a);
        d.matvec_t(&r, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn col_dot_and_axpy() {
        let m = small();
        assert_eq!(m.col_dot(0, &[1.0, 1.0, 1.0]), 5.0);
        let mut r = vec![0.0; 3];
        m.col_axpy(2, 2.0, &mut r);
        assert_eq!(r, vec![4.0, 0.0, 10.0]);
    }

    #[test]
    fn col_pair_dot_matches_dense() {
        let m = small();
        let d = m.to_dense();
        for a in 0..3 {
            for b in 0..3 {
                let expect: f64 =
                    (0..3).map(|i| d.get(i, a) * d.get(i, b)).sum();
                assert!(
                    (m.col_pair_dot(a, b) - expect).abs() < 1e-14,
                    "pair ({a},{b})"
                );
            }
        }
    }

    #[test]
    fn col_sq_norms_match_dense() {
        let m = small();
        assert_eq!(m.col_sq_norms(), vec![17.0, 9.0, 29.0]);
    }

    #[test]
    fn matvec_t_range_matches_full() {
        let m = small();
        let r = [1.0, 2.0, 3.0];
        let mut full = vec![0.0; 3];
        m.matvec_t(&r, &mut full);
        let mut sub = vec![0.0; 2];
        m.matvec_t_range(&r, 1..3, &mut sub);
        assert_eq!(sub, &full[1..3]);
        let mut empty: Vec<f64> = vec![];
        m.matvec_t_range(&r, 2..2, &mut empty);
    }

    #[test]
    fn scale_cols_matches_scalar_loop() {
        let mut a = small();
        let mut b = small();
        let scales = [0.5, 1.0, -2.0];
        a.scale_cols(&scales, 4);
        for (j, &s) in scales.iter().enumerate() {
            b.scale_col(j, s);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn scale_col_works() {
        let mut m = small();
        m.scale_col(0, 0.5);
        assert_eq!(m.col(0).1, &[0.5, 2.0]);
    }

    #[test]
    fn density() {
        let m = small();
        assert!((m.density() - 5.0 / 9.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn triplet_oob_panics() {
        CscMatrix::from_triplets(2, 2, &[(2, 0, 1.0)]);
    }
}
