//! Linear-algebra substrate: dense column-major and CSC sparse matrices,
//! plus the [`Design`] abstraction the solvers are generic over.

pub mod dense;
pub mod design;
pub mod sparse;

pub use dense::{axpy, dot, norm1, norm_inf, nrm2, sq_nrm2, DenseMatrix};
pub use design::Design;
pub use sparse::CscMatrix;
