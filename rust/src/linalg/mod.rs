//! Linear-algebra substrate: dense column-major and CSC sparse matrices,
//! the [`Design`] abstraction the solvers are generic over, and the
//! kernel engine ([`parallel`]) that runs the O(n·p) column passes
//! blocked and multi-threaded under a global thread budget.

pub mod dense;
pub mod design;
pub mod gram;
pub mod parallel;
pub mod simd;
pub mod sparse;

pub use dense::{axpy, dot, norm1, norm_inf, nrm2, sq_nrm2, DenseMatrix};
pub use design::{group_reduce_sq, Design};
pub use gram::{GramCache, GramStore};
pub use parallel::KernelPolicy;
pub use simd::{KernelIsa, Precision, ShadowF32};
pub use sparse::CscMatrix;
