//! Dense column-major design matrix and the vector kernels the solver's
//! hot loop is built from.
//!
//! Coordinate descent touches one column at a time, so the design matrix is
//! stored column-major: `X[:, j]` is a contiguous slice. The kernels here
//! (dot, axpy, nrm2) are written so LLVM auto-vectorises them; the 4-way
//! manually unrolled variants exist because rustc does not always unroll
//! reductions profitably on its own (measured in `benches/micro_kernels.rs`).

/// Dense matrix, column-major (Fortran order), `n` rows × `p` columns.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    n: usize,
    p: usize,
    /// Column-major storage, length `n * p`.
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Build from column-major storage. Panics if `data.len() != n * p`.
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * p, "column-major buffer has wrong length");
        Self { n, p, data }
    }

    /// Build from row-major storage (as a literature-style `[[row], ..]`).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n = rows.len();
        let p = if n == 0 { 0 } else { rows[0].len() };
        let mut data = vec![0.0; n * p];
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), p, "ragged rows");
            for (j, &v) in r.iter().enumerate() {
                data[j * n + i] = v;
            }
        }
        Self { n, p, data }
    }

    pub fn zeros(n: usize, p: usize) -> Self {
        Self { n, p, data: vec![0.0; n * p] }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Contiguous column slice `X[:, j]`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.p);
        &self.data[j * self.n..(j + 1) * self.n]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.p);
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// Raw column-major buffer (used by the PJRT bridge, which wants f32).
    pub fn raw(&self) -> &[f64] {
        &self.data
    }

    /// `X β` into `out` (length n). `beta` has length p.
    pub fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for j in 0..self.p {
            let b = beta[j];
            if b != 0.0 {
                axpy(b, self.col(j), out);
            }
        }
    }

    /// `Xᵀ r` into `out` (length p). `r` has length n.
    pub fn matvec_t(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for j in 0..self.p {
            out[j] = dot(self.col(j), r);
        }
    }

    /// Squared ℓ2 norms of all columns.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.p).map(|j| sq_nrm2(self.col(j))).collect()
    }

    /// Scale column j in place.
    pub fn scale_col(&mut self, j: usize, s: f64) {
        for v in self.col_mut(j) {
            *v *= s;
        }
    }
}

/// Dot product with 4-way unrolled accumulators (keeps the FP dependency
/// chain short so the compiler vectorises the reduction).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn sq_nrm2(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    sq_nrm2(x).sqrt()
}

/// ℓ∞ norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// ℓ1 norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_rows_round_trip() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m.nrows(), 3);
        assert_eq!(m.ncols(), 2);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(2, 1), 6.0);
        assert_eq!(m.col(1), &[2.0, 4.0, 6.0]);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut out = vec![0.0; 2];
        m.matvec(&[1.0, -1.0], &mut out);
        assert_eq!(out, vec![-1.0, -1.0]);
    }

    #[test]
    fn matvec_t_matches_manual() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut out = vec![0.0; 2];
        m.matvec_t(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![4.0, 6.0]);
    }

    #[test]
    fn dot_handles_remainder_lengths() {
        for n in 0..9 {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5).collect();
            let expect: f64 = (0..n).map(|i| (i * i) as f64 * 0.5).sum();
            assert!((dot(&a, &b) - expect).abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-15);
        assert_eq!(norm_inf(&x), 4.0);
        assert_eq!(norm1(&x), 7.0);
    }

    #[test]
    fn col_sq_norms_and_scale() {
        let mut m = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![2.0, 3.0]]);
        assert_eq!(m.col_sq_norms(), vec![5.0, 9.0]);
        m.scale_col(1, 2.0);
        assert_eq!(m.col(1), &[0.0, 6.0]);
    }
}
