//! Dense column-major design matrix and the vector kernels the solver's
//! hot loop is built from.
//!
//! Coordinate descent touches one column at a time, so the design matrix is
//! stored column-major: `X[:, j]` is a contiguous slice. The public kernels
//! (dot, axpy, the blocked panels) dispatch on the runtime-probed
//! [`super::simd::KernelIsa`]; the `*_scalar` variants are the historical
//! portable implementations, kept verbatim both as the `--isa scalar`
//! floor (bit-identical to the pre-SIMD kernels) and as the reference the
//! vector kernels are property-tested against.

/// Panel width of the blocked `Xᵀr` micro-kernel: 8 f64 accumulators fit
/// comfortably in vector registers while multiplying the reuse of each
/// loaded residual element by 8.
pub const PANEL: usize = 8;

/// Dense matrix, column-major (Fortran order), `n` rows × `p` columns.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    n: usize,
    p: usize,
    /// Column-major storage, length `n * p`.
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Build from column-major storage. Panics if `data.len() != n * p`.
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * p, "column-major buffer has wrong length");
        Self { n, p, data }
    }

    /// Build from row-major storage (as a literature-style `[[row], ..]`).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n = rows.len();
        let p = if n == 0 { 0 } else { rows[0].len() };
        let mut data = vec![0.0; n * p];
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), p, "ragged rows");
            for (j, &v) in r.iter().enumerate() {
                data[j * n + i] = v;
            }
        }
        Self { n, p, data }
    }

    pub fn zeros(n: usize, p: usize) -> Self {
        Self { n, p, data: vec![0.0; n * p] }
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Contiguous column slice `X[:, j]`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.p);
        &self.data[j * self.n..(j + 1) * self.n]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.p);
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// Raw column-major buffer (used by the PJRT bridge, which wants f32).
    pub fn raw(&self) -> &[f64] {
        &self.data
    }

    /// `X β` into `out` (length n). `beta` has length p.
    pub fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.p);
        assert_eq!(out.len(), self.n);
        out.fill(0.0);
        for j in 0..self.p {
            let b = beta[j];
            if b != 0.0 {
                axpy(b, self.col(j), out);
            }
        }
    }

    /// `Xᵀ r` into `out` (length p). `r` has length n. Serial per-column
    /// reference; the kernel engine's blocked/parallel variant is
    /// [`DenseMatrix::matvec_t_panel`] (routed via `Design::matvec_t`).
    pub fn matvec_t(&self, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), self.p);
        for j in 0..self.p {
            out[j] = dot(self.col(j), r);
        }
    }

    /// Blocked `Xᵀ r` over the column range `cols`: writes
    /// `out[k] = X[:, cols.start + k]ᵀ r`, dispatched on the active
    /// [`super::simd::KernelIsa`]. Under a vector ISA every output is the
    /// dispatched [`dot`] of its column; under `--isa scalar` this is the
    /// historical panel kernel, bit-identical to the pre-SIMD code.
    pub fn matvec_t_panel(&self, r: &[f64], cols: std::ops::Range<usize>, out: &mut [f64]) {
        super::simd::matvec_t_panel(self, r, cols, out)
    }

    /// The historical scalar panel kernel: columns are processed
    /// [`PANEL`] at a time so every loaded element of `r` is reused across
    /// the panel — the cache win over per-column [`dot`] (measured in
    /// `benches/micro_kernels.rs`). Panel membership is determined by the
    /// absolute column index when `cols.start` is PANEL-aligned (the
    /// kernel engine aligns its chunks), so results are independent of
    /// how the column space was split across threads.
    pub(crate) fn matvec_t_panel_scalar(
        &self,
        r: &[f64],
        cols: std::ops::Range<usize>,
        out: &mut [f64],
    ) {
        assert_eq!(r.len(), self.n);
        assert!(cols.end <= self.p);
        assert_eq!(out.len(), cols.end - cols.start);
        let n = self.n;
        let mut j = cols.start;
        let mut o = 0usize;
        while j + PANEL <= cols.end {
            let c0 = self.col(j);
            let c1 = self.col(j + 1);
            let c2 = self.col(j + 2);
            let c3 = self.col(j + 3);
            let c4 = self.col(j + 4);
            let c5 = self.col(j + 5);
            let c6 = self.col(j + 6);
            let c7 = self.col(j + 7);
            let mut acc = [0.0f64; PANEL];
            for i in 0..n {
                let ri = r[i];
                acc[0] += c0[i] * ri;
                acc[1] += c1[i] * ri;
                acc[2] += c2[i] * ri;
                acc[3] += c3[i] * ri;
                acc[4] += c4[i] * ri;
                acc[5] += c5[i] * ri;
                acc[6] += c6[i] * ri;
                acc[7] += c7[i] * ri;
            }
            out[o..o + PANEL].copy_from_slice(&acc);
            j += PANEL;
            o += PANEL;
        }
        while j < cols.end {
            out[o] = dot_scalar(self.col(j), r);
            j += 1;
            o += 1;
        }
    }

    /// Multi-RHS blocked `Xᵀ R` over the column range `cols`: `R` is a
    /// residual **panel** of `n_rhs` column-major vectors (`R[:, c] =
    /// r[c·n .. (c+1)·n]`) and the output is feature-major
    /// (`out[(j − cols.start)·n_rhs + c] = X[:, j]ᵀ R[:, c]`), so a
    /// PANEL-aligned column split maps to a contiguous output split —
    /// the batched-fit scoring kernel (FaSTGLZ): each loaded design
    /// element is reused across all `n_rhs` fits *and* across the 8-wide
    /// column panel.
    ///
    /// Bitwise contract: for every `(j, c)` the result is identical to
    /// [`DenseMatrix::matvec_t_panel`] on `R[:, c]` alone under the same
    /// active ISA, so batched scoring reproduces single-fit scoring
    /// bit-for-bit and stays independent of the thread split.
    pub fn matmul_t_panel(
        &self,
        r: &[f64],
        n_rhs: usize,
        cols: std::ops::Range<usize>,
        out: &mut [f64],
    ) {
        super::simd::matmul_t_panel(self, r, n_rhs, cols, out)
    }

    /// The historical scalar multi-RHS kernel (i-ascending inside full
    /// panels, [`dot_scalar`] on the remainder columns) — the
    /// `--isa scalar` floor.
    pub(crate) fn matmul_t_panel_scalar(
        &self,
        r: &[f64],
        n_rhs: usize,
        cols: std::ops::Range<usize>,
        out: &mut [f64],
    ) {
        assert_eq!(r.len(), self.n * n_rhs);
        assert!(cols.end <= self.p);
        assert_eq!(out.len(), (cols.end - cols.start) * n_rhs);
        if n_rhs == 1 {
            return self.matvec_t_panel_scalar(r, cols, out);
        }
        if n_rhs == 0 {
            return;
        }
        let n = self.n;
        // 8 × n_rhs accumulator block, [k·n_rhs + c] — matches the output
        // layout so a full panel flushes with one copy
        let mut acc = vec![0.0f64; PANEL * n_rhs];
        let mut j = cols.start;
        let mut o = 0usize;
        while j + PANEL <= cols.end {
            let c0 = self.col(j);
            let c1 = self.col(j + 1);
            let c2 = self.col(j + 2);
            let c3 = self.col(j + 3);
            let c4 = self.col(j + 4);
            let c5 = self.col(j + 5);
            let c6 = self.col(j + 6);
            let c7 = self.col(j + 7);
            acc.fill(0.0);
            for i in 0..n {
                let x = [c0[i], c1[i], c2[i], c3[i], c4[i], c5[i], c6[i], c7[i]];
                for c in 0..n_rhs {
                    let ri = r[c * n + i];
                    let a = &mut acc[c..];
                    a[0] += x[0] * ri;
                    a[n_rhs] += x[1] * ri;
                    a[2 * n_rhs] += x[2] * ri;
                    a[3 * n_rhs] += x[3] * ri;
                    a[4 * n_rhs] += x[4] * ri;
                    a[5 * n_rhs] += x[5] * ri;
                    a[6 * n_rhs] += x[6] * ri;
                    a[7 * n_rhs] += x[7] * ri;
                }
            }
            out[o..o + PANEL * n_rhs].copy_from_slice(&acc);
            j += PANEL;
            o += PANEL * n_rhs;
        }
        while j < cols.end {
            let col = self.col(j);
            for c in 0..n_rhs {
                out[o + c] = dot_scalar(col, &r[c * n..(c + 1) * n]);
            }
            j += 1;
            o += n_rhs;
        }
    }

    /// Gathered blocked dots: `out[k] = X[:, cols[k]]ᵀ r` for an
    /// **arbitrary** (not necessarily contiguous) column list — the
    /// working-set Gram assembly kernel (`r` is itself a design column
    /// there), dispatched on the active [`super::simd::KernelIsa`].
    pub fn gather_dots_panel(&self, r: &[f64], cols: &[usize], out: &mut [f64]) {
        super::simd::gather_dots_panel(self, r, cols, out)
    }

    /// The historical scalar gather kernel: columns are processed
    /// [`PANEL`] at a time so every loaded element of `r` is reused
    /// across the panel. Each panel's summation order depends only on
    /// the position inside `cols`, so splitting `cols` across threads at
    /// PANEL-aligned boundaries keeps results thread-count independent.
    pub(crate) fn gather_dots_panel_scalar(&self, r: &[f64], cols: &[usize], out: &mut [f64]) {
        assert_eq!(r.len(), self.n);
        assert_eq!(out.len(), cols.len());
        let n = self.n;
        let mut k = 0usize;
        while k + PANEL <= cols.len() {
            let c0 = self.col(cols[k]);
            let c1 = self.col(cols[k + 1]);
            let c2 = self.col(cols[k + 2]);
            let c3 = self.col(cols[k + 3]);
            let c4 = self.col(cols[k + 4]);
            let c5 = self.col(cols[k + 5]);
            let c6 = self.col(cols[k + 6]);
            let c7 = self.col(cols[k + 7]);
            let mut acc = [0.0f64; PANEL];
            for i in 0..n {
                let ri = r[i];
                acc[0] += c0[i] * ri;
                acc[1] += c1[i] * ri;
                acc[2] += c2[i] * ri;
                acc[3] += c3[i] * ri;
                acc[4] += c4[i] * ri;
                acc[5] += c5[i] * ri;
                acc[6] += c6[i] * ri;
                acc[7] += c7[i] * ri;
            }
            out[k..k + PANEL].copy_from_slice(&acc);
            k += PANEL;
        }
        while k < cols.len() {
            out[k] = dot_scalar(self.col(cols[k]), r);
            k += 1;
        }
    }

    /// Scale every column `j` by `scales[j]`, parallelised over the
    /// kernel pool (each task owns a disjoint column range of the
    /// column-major backing store).
    pub fn scale_cols(&mut self, scales: &[f64], threads: usize) {
        assert_eq!(scales.len(), self.p);
        if self.n == 0 || self.p == 0 {
            return;
        }
        let n = self.n;
        let col_ranges =
            super::parallel::even_chunks(self.p, super::parallel::chunk_count(threads));
        let data_ranges: Vec<std::ops::Range<usize>> =
            col_ranges.iter().map(|r| r.start * n..r.end * n).collect();
        super::parallel::par_slices(&mut self.data, &data_ranges, threads, |k, _, sub| {
            let cols = col_ranges[k].clone();
            for (c, col) in sub.chunks_mut(n).enumerate() {
                let s = scales[cols.start + c];
                if s != 1.0 {
                    for v in col {
                        *v *= s;
                    }
                }
            }
        });
    }

    /// Squared ℓ2 norms of all columns.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        (0..self.p).map(|j| sq_nrm2(self.col(j))).collect()
    }

    /// Scale column j in place.
    pub fn scale_col(&mut self, j: usize, s: f64) {
        for v in self.col_mut(j) {
            *v *= s;
        }
    }
}

/// Dot product, dispatched on the active [`super::simd::KernelIsa`].
/// Non-FMA ISAs (incl. `--isa scalar`) are bit-exact against the scalar
/// `dot_scalar`; FMA ISAs agree to ≤ 1e-12 relative.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    super::simd::dot(a, b)
}

/// The historical scalar dot: 4-way unrolled accumulators (lane ℓ owns
/// indices `4k+ℓ`), reduced `(s0+s1)+(s2+s3)`, sequential tail. The
/// vector kernels reproduce exactly this lane order.
#[inline]
pub(crate) fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`, dispatched on the active [`super::simd::KernelIsa`].
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    super::simd::axpy(alpha, x, y)
}

/// The historical scalar axpy (element-wise, so every non-FMA vector
/// variant is bit-exact against it).
#[inline]
pub(crate) fn axpy_scalar(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn sq_nrm2(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    sq_nrm2(x).sqrt()
}

/// ℓ∞ norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// ℓ1 norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_rows_round_trip() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m.nrows(), 3);
        assert_eq!(m.ncols(), 2);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(2, 1), 6.0);
        assert_eq!(m.col(1), &[2.0, 4.0, 6.0]);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut out = vec![0.0; 2];
        m.matvec(&[1.0, -1.0], &mut out);
        assert_eq!(out, vec![-1.0, -1.0]);
    }

    #[test]
    fn matvec_t_matches_manual() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut out = vec![0.0; 2];
        m.matvec_t(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![4.0, 6.0]);
    }

    #[test]
    fn dot_handles_remainder_lengths() {
        for n in 0..9 {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5).collect();
            let expect: f64 = (0..n).map(|i| (i * i) as f64 * 0.5).sum();
            assert!((dot(&a, &b) - expect).abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &[1.0, 2.0, 3.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-15);
        assert_eq!(norm_inf(&x), 4.0);
        assert_eq!(norm1(&x), 7.0);
    }

    #[test]
    fn panel_matches_per_column_dot_across_remainders() {
        // shapes straddling the panel width, incl. empty and one column
        for (n, p) in [(0usize, 0usize), (3, 0), (0, 5), (4, 1), (5, 7), (6, 8), (7, 9), (3, 17)] {
            let data: Vec<f64> = (0..n * p).map(|k| ((k * 37 % 19) as f64) - 9.0).collect();
            let m = DenseMatrix::from_col_major(n, p, data);
            let r: Vec<f64> = (0..n).map(|i| (i as f64) * 0.25 - 1.0).collect();
            let mut reference = vec![0.0; p];
            m.matvec_t(&r, &mut reference);
            let mut panel = vec![0.0; p];
            m.matvec_t_panel(&r, 0..p, &mut panel);
            for j in 0..p {
                assert!((panel[j] - reference[j]).abs() < 1e-12, "n={n} p={p} j={j}");
            }
            // and over a sub-range
            if p >= 3 {
                let mut sub = vec![0.0; p - 2];
                m.matvec_t_panel(&r, 1..p - 1, &mut sub);
                for (k, j) in (1..p - 1).enumerate() {
                    assert!((sub[k] - reference[j]).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn gather_dots_panel_matches_per_column_dot() {
        for p in [0usize, 1, 7, 8, 9, 19] {
            let n = 5;
            let data: Vec<f64> = (0..n * p).map(|k| ((k * 13 % 11) as f64) - 5.0).collect();
            let m = DenseMatrix::from_col_major(n, p, data);
            let r: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 1.0).collect();
            // scattered, repeated column list
            let cols: Vec<usize> = (0..p).rev().chain(0..p.min(3)).collect();
            let mut out = vec![0.0; cols.len()];
            m.gather_dots_panel(&r, &cols, &mut out);
            for (k, &j) in cols.iter().enumerate() {
                let expect = dot(m.col(j), &r);
                assert!((out[k] - expect).abs() < 1e-12, "p={p} k={k}");
            }
        }
    }

    #[test]
    fn scale_cols_matches_scalar_loop() {
        let mut a = DenseMatrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let mut b = a.clone();
        let scales = [2.0, 1.0, -0.5];
        a.scale_cols(&scales, 4);
        for (j, &s) in scales.iter().enumerate() {
            b.scale_col(j, s);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn col_sq_norms_and_scale() {
        let mut m = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![2.0, 3.0]]);
        assert_eq!(m.col_sq_norms(), vec![5.0, 9.0]);
        m.scale_col(1, 2.0);
        assert_eq!(m.col(1), &[0.0, 6.0]);
    }
}
