//! Design-matrix abstraction: the solver is generic over dense
//! (column-major) and sparse (CSC) storage through this enum.
//!
//! An enum rather than a trait object: the CD hot loop calls `col_dot` /
//! `col_axpy` millions of times, and a two-arm match is cheaper and more
//! inlinable than a virtual call. All solver code takes `&Design`.

use super::dense::{DenseMatrix, PANEL};
use super::parallel::{self, KernelPolicy};
use super::sparse::CscMatrix;

/// A dense or sparse design matrix.
#[derive(Clone, Debug)]
pub enum Design {
    Dense(DenseMatrix),
    Sparse(CscMatrix),
}

impl Design {
    #[inline]
    pub fn nrows(&self) -> usize {
        match self {
            Design::Dense(m) => m.nrows(),
            Design::Sparse(m) => m.nrows(),
        }
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        match self {
            Design::Dense(m) => m.ncols(),
            Design::Sparse(m) => m.ncols(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Design::Sparse(_))
    }

    /// `X[:, j]ᵀ r`.
    #[inline]
    pub fn col_dot(&self, j: usize, r: &[f64]) -> f64 {
        match self {
            Design::Dense(m) => super::dense::dot(m.col(j), r),
            Design::Sparse(m) => m.col_dot(j, r),
        }
    }

    /// `r += c · X[:, j]`.
    #[inline]
    pub fn col_axpy(&self, j: usize, c: f64, r: &mut [f64]) {
        match self {
            Design::Dense(m) => super::dense::axpy(c, m.col(j), r),
            Design::Sparse(m) => m.col_axpy(j, c, r),
        }
    }

    /// Mapped column dot: `Σ_i X_ij · f(i, state_i)` over the stored
    /// entries of column j. This is the generic-datafit hot primitive —
    /// e.g. logistic CD needs `Σ_i X_ij · (−y_i σ(−y_i (Xβ)_i))/n` without
    /// materialising the elementwise weights.
    #[inline]
    pub fn col_dot_map<F: FnMut(usize, f64) -> f64>(
        &self,
        j: usize,
        state: &[f64],
        mut f: F,
    ) -> f64 {
        match self {
            Design::Dense(m) => {
                let col = m.col(j);
                let mut s = 0.0;
                for (i, &x) in col.iter().enumerate() {
                    s += x * f(i, state[i]);
                }
                s
            }
            Design::Sparse(m) => {
                let (rows, vals) = m.col(j);
                let mut s = 0.0;
                for (&i, &v) in rows.iter().zip(vals.iter()) {
                    let i = i as usize;
                    s += v * f(i, state[i]);
                }
                s
            }
        }
    }

    /// Weighted squared column norm `Σ_i w_i X_ij²` over the stored
    /// entries of column j — the prox-Newton subproblem's per-coordinate
    /// Lipschitz constant (w = per-sample Hessian diagonal). Only the
    /// working-set columns are touched per outer iteration, so this stays
    /// a column kernel rather than a full-design pass.
    #[inline]
    pub fn col_weighted_sq_norm(&self, j: usize, w: &[f64]) -> f64 {
        match self {
            Design::Dense(m) => {
                let col = m.col(j);
                let mut s = 0.0;
                for (i, &x) in col.iter().enumerate() {
                    s += w[i] * x * x;
                }
                s
            }
            Design::Sparse(m) => {
                let (rows, vals) = m.col(j);
                let mut s = 0.0;
                for (&i, &v) in rows.iter().zip(vals.iter()) {
                    s += w[i as usize] * v * v;
                }
                s
            }
        }
    }

    /// `X β`.
    pub fn matvec(&self, beta: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => m.matvec(beta, out),
            Design::Sparse(m) => m.matvec(beta, out),
        }
    }

    /// `Xᵀ r` — the O(n·p) scoring-pass kernel, routed through the kernel
    /// engine: blocked panels for dense, nnz-balanced column chunks for
    /// CSC, parallel above the policy's work threshold.
    pub fn matvec_t(&self, r: &[f64], out: &mut [f64]) {
        let threads = KernelPolicy::global().threads_for(self.stored_entries());
        self.matvec_t_threads(r, out, threads);
    }

    /// [`Design::matvec_t`] with an explicit thread count (1 = the blocked
    /// serial kernel). Benches and equivalence tests call this directly;
    /// `matvec_t` applies the global [`KernelPolicy`].
    pub fn matvec_t_threads(&self, r: &[f64], out: &mut [f64], threads: usize) {
        assert_eq!(r.len(), self.nrows());
        assert_eq!(out.len(), self.ncols());
        match self {
            Design::Dense(m) => {
                let ranges = parallel::even_chunks_aligned(
                    m.ncols(),
                    parallel::chunk_count(threads),
                    PANEL,
                );
                parallel::par_slices(out, &ranges, threads, |_, cols, sub| {
                    m.matvec_t_panel(r, cols, sub)
                });
            }
            Design::Sparse(m) => {
                let ranges =
                    parallel::balanced_chunks(m.indptr(), parallel::chunk_count(threads));
                parallel::par_slices(out, &ranges, threads, |_, cols, sub| {
                    m.matvec_t_range(r, cols, sub)
                });
            }
        }
    }

    /// Multi-RHS `Xᵀ R` for a residual panel `R ∈ ℝ^{n×B}` (column-major,
    /// `B = n_rhs`), output feature-major (`out[j·B + c]`) — the batched
    /// scoring pass (FaSTGLZ). One read of the design serves all `B`
    /// sibling fits. Routed through the kernel engine exactly like
    /// [`Design::matvec_t`]: PANEL-aligned column splits for dense,
    /// nnz-balanced column chunks for CSC — and because the per-`(j, c)`
    /// summation order is chunk-independent, the result is bit-identical
    /// across thread counts *and* to `B` single-RHS `matvec_t` calls.
    pub fn matmul_t(&self, r: &[f64], n_rhs: usize, out: &mut [f64]) {
        let work = self.stored_entries().saturating_mul(n_rhs.max(1));
        let threads = KernelPolicy::global().threads_for(work);
        self.matmul_t_threads(r, n_rhs, out, threads);
    }

    /// [`Design::matmul_t`] with an explicit thread count (1 = the blocked
    /// serial kernel). Benches and bit-invariance tests call this
    /// directly.
    pub fn matmul_t_threads(&self, r: &[f64], n_rhs: usize, out: &mut [f64], threads: usize) {
        assert_eq!(r.len(), self.nrows() * n_rhs);
        assert_eq!(out.len(), self.ncols() * n_rhs);
        if n_rhs == 0 {
            return;
        }
        match self {
            Design::Dense(m) => {
                let col_ranges = parallel::even_chunks_aligned(
                    m.ncols(),
                    parallel::chunk_count(threads),
                    PANEL,
                );
                // output ranges are the column ranges scaled by the panel
                // width (feature-major layout keeps a column split
                // contiguous in the output)
                let out_ranges: Vec<std::ops::Range<usize>> = col_ranges
                    .iter()
                    .map(|c| c.start * n_rhs..c.end * n_rhs)
                    .collect();
                parallel::par_slices(out, &out_ranges, threads, |k, _, sub| {
                    m.matmul_t_panel(r, n_rhs, col_ranges[k].clone(), sub)
                });
            }
            Design::Sparse(m) => {
                let col_ranges =
                    parallel::balanced_chunks(m.indptr(), parallel::chunk_count(threads));
                let out_ranges: Vec<std::ops::Range<usize>> = col_ranges
                    .iter()
                    .map(|c| c.start * n_rhs..c.end * n_rhs)
                    .collect();
                parallel::par_slices(out, &out_ranges, threads, |k, _, sub| {
                    m.matmul_t_range(r, n_rhs, col_ranges[k].clone(), sub)
                });
            }
        }
    }

    /// Weighted axpy over stored entries: `r_i += c · X_ij · w_i`. The
    /// panel-resident residual update for row-masked batch members (CV
    /// folds batched as 0/1 row weights): masked-out rows contribute
    /// `±0.0` and therefore stay exactly zero in the panel column.
    #[inline]
    pub fn col_axpy_weighted(&self, j: usize, c: f64, w: &[f64], r: &mut [f64]) {
        match self {
            Design::Dense(m) => {
                let col = m.col(j);
                for (i, &x) in col.iter().enumerate() {
                    r[i] += c * x * w[i];
                }
            }
            Design::Sparse(m) => {
                let (rows, vals) = m.col(j);
                for (&i, &v) in rows.iter().zip(vals.iter()) {
                    let i = i as usize;
                    r[i] += c * v * w[i];
                }
            }
        }
    }

    /// Panel axpy: commit per-fit CD deltas for column `j` into every
    /// panel column at once — `R[:, c] += coefs[c] · X[:, j]` for each
    /// `c` with a nonzero delta. One design-column read serves all `B`
    /// residual updates; per panel column this is exactly
    /// [`Design::col_axpy`], so batched commits match scalar commits
    /// bitwise.
    pub fn col_axpy_panel(&self, j: usize, coefs: &[f64], panel: &mut [f64]) {
        let n = self.nrows();
        assert_eq!(panel.len(), n * coefs.len());
        for (c, &a) in coefs.iter().enumerate() {
            if a != 0.0 {
                self.col_axpy(j, a, &mut panel[c * n..(c + 1) * n]);
            }
        }
    }

    /// `Xᵀ r` restricted to a subset of columns (the working set); writes
    /// `out[k] = X[:, ws[k]]ᵀ r`. Parallelised over nnz-balanced slices of
    /// `ws` when the restricted pass is big enough.
    pub fn matvec_t_subset(&self, r: &[f64], ws: &[usize], out: &mut [f64]) {
        assert_eq!(ws.len(), out.len());
        let work = self.subset_stored_entries(ws);
        let threads = KernelPolicy::global().threads_for(work);
        if threads == 1 {
            for (o, &j) in out.iter_mut().zip(ws.iter()) {
                *o = self.col_dot(j, r);
            }
            return;
        }
        let ranges = self.subset_chunks(ws, threads);
        parallel::par_slices(out, &ranges, threads, |_, rng, sub| {
            for (o, &j) in sub.iter_mut().zip(ws[rng].iter()) {
                *o = self.col_dot(j, r);
            }
        });
    }

    /// `Xᵀr` gathered in block-partition order: `out[k] = X[:, cols[k]]ᵀ r`
    /// where `cols` is a partition's flattened column order
    /// (`BlockPartition::flat_indices`). When the partition keeps the
    /// natural column order (scalar / contiguous groups / multitask rows)
    /// this *is* `matvec_t` — the blocked panel / nnz-balanced CSC kernel
    /// of the kernel engine; scattered groups route through the
    /// nnz-balanced subset kernel. This is the grouped scoring pass's
    /// O(n·p) hot spot.
    pub fn matvec_t_groups(&self, r: &[f64], cols: &[usize], out: &mut [f64]) {
        if cols.len() == self.ncols() && cols.iter().enumerate().all(|(k, &j)| k == j) {
            self.matvec_t(r, out);
        } else {
            self.matvec_t_subset(r, cols, out);
        }
    }

    /// Per-group squared Frobenius norms `‖X_b‖_F² = Σ_{j∈b} ‖X_j‖²`:
    /// the grouped block-Lipschitz bounds and the gap-safe block-screening
    /// radii. `cols`/`offsets` are a partition's flattened column order
    /// and block boundaries; the column-norm pass runs on the kernel
    /// engine, the per-group reduction is O(p).
    pub fn group_sq_norms(&self, cols: &[usize], offsets: &[usize]) -> Vec<f64> {
        let mut sq = vec![0.0; self.ncols()];
        self.col_sq_norms_into(&mut sq);
        group_reduce_sq(&sq, cols, offsets)
    }

    /// Stored entries touched by one pass over the columns of `ws`
    /// (`n·|ws|` dense, Σ nnz sparse) — the work unit of the kernel
    /// policy and of the inner-engine cost model (a residual CD epoch is
    /// two such passes; see `solver::gram`).
    pub fn subset_stored_entries(&self, ws: &[usize]) -> usize {
        match self {
            Design::Dense(m) => m.nrows() * ws.len(),
            Design::Sparse(m) => ws.iter().map(|&j| m.col_nnz(j)).sum(),
        }
    }


    /// Chunk `0..ws.len()`: even for dense, nnz-balanced for CSC.
    fn subset_chunks(&self, ws: &[usize], threads: usize) -> Vec<std::ops::Range<usize>> {
        match self {
            Design::Dense(_) => parallel::even_chunks(ws.len(), parallel::chunk_count(threads)),
            Design::Sparse(m) => {
                let mut cum = Vec::with_capacity(ws.len() + 1);
                cum.push(0usize);
                for &j in ws {
                    cum.push(cum.last().unwrap() + m.col_nnz(j));
                }
                parallel::balanced_chunks(&cum, parallel::chunk_count(threads))
            }
        }
    }

    /// Squared ℓ2 norms of all columns.
    pub fn col_sq_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.ncols()];
        self.col_sq_norms_into(&mut out);
        out
    }

    /// Buffer-reusing [`Design::col_sq_norms`] (per-solve allocation
    /// killer — ISSUE 2 satellite), kernel-engine parallel.
    pub fn col_sq_norms_into(&self, out: &mut [f64]) {
        let threads = KernelPolicy::global().threads_for(self.stored_entries());
        self.col_sq_norms_threads(out, threads);
    }

    /// [`Design::col_sq_norms_into`] with an explicit thread count.
    pub fn col_sq_norms_threads(&self, out: &mut [f64], threads: usize) {
        assert_eq!(out.len(), self.ncols());
        match self {
            Design::Dense(m) => {
                let ranges =
                    parallel::even_chunks(m.ncols(), parallel::chunk_count(threads));
                parallel::par_slices(out, &ranges, threads, |_, cols, sub| {
                    for (o, j) in sub.iter_mut().zip(cols) {
                        *o = super::dense::sq_nrm2(m.col(j));
                    }
                });
            }
            Design::Sparse(m) => {
                let ranges =
                    parallel::balanced_chunks(m.indptr(), parallel::chunk_count(threads));
                parallel::par_slices(out, &ranges, threads, |_, cols, sub| {
                    for (o, j) in sub.iter_mut().zip(cols) {
                        let (_, vals) = m.col(j);
                        *o = vals.iter().map(|v| v * v).sum();
                    }
                });
            }
        }
    }

    /// Normalise columns to have norm `target` (paper: √n for MCP).
    /// Zero columns are left untouched. Returns the applied scales.
    /// Both the norm pass and the scaling run on the kernel engine.
    pub fn normalize_cols(&mut self, target: f64) -> Vec<f64> {
        let p = self.ncols();
        let mut norms = vec![0.0; p];
        self.col_sq_norms_into(&mut norms);
        let mut scales = vec![1.0; p];
        for (j, &nsq) in norms.iter().enumerate() {
            let nrm = nsq.sqrt();
            if nrm > 0.0 {
                scales[j] = target / nrm;
            }
        }
        let threads = KernelPolicy::global().threads_for(self.stored_entries());
        match self {
            Design::Dense(m) => m.scale_cols(&scales, threads),
            Design::Sparse(m) => m.scale_cols(&scales, threads),
        }
        scales
    }

    /// Number of stored entries (n·p for dense).
    pub fn stored_entries(&self) -> usize {
        match self {
            Design::Dense(m) => m.nrows() * m.ncols(),
            Design::Sparse(m) => m.nnz(),
        }
    }
}

/// Reduce per-column squared norms to per-group sums given a partition's
/// flattened column order and block boundaries (shared by
/// [`Design::group_sq_norms`] and callers holding a cached Gram diagonal).
pub fn group_reduce_sq(col_sq: &[f64], cols: &[usize], offsets: &[usize]) -> Vec<f64> {
    offsets
        .windows(2)
        .map(|w| cols[w[0]..w[1]].iter().map(|&j| col_sq[j]).sum())
        .collect()
}

impl From<DenseMatrix> for Design {
    fn from(m: DenseMatrix) -> Self {
        Design::Dense(m)
    }
}

impl From<CscMatrix> for Design {
    fn from(m: CscMatrix) -> Self {
        Design::Sparse(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Design, Design) {
        let dense = DenseMatrix::from_rows(&[
            vec![1.0, 0.0, 2.0],
            vec![0.0, 3.0, 0.0],
            vec![4.0, 0.0, 5.0],
        ]);
        let sparse = CscMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 1.0), (2, 0, 4.0), (1, 1, 3.0), (0, 2, 2.0), (2, 2, 5.0)],
        );
        (Design::Dense(dense), Design::Sparse(sparse))
    }

    #[test]
    fn dense_and_sparse_agree_on_everything() {
        let (d, s) = pair();
        let r = [1.0, -1.0, 2.0];
        let beta = [0.5, 1.0, -1.0];
        for j in 0..3 {
            assert_eq!(d.col_dot(j, &r), s.col_dot(j, &r), "col_dot {j}");
        }
        let (mut od, mut os) = (vec![0.0; 3], vec![0.0; 3]);
        d.matvec(&beta, &mut od);
        s.matvec(&beta, &mut os);
        assert_eq!(od, os);
        d.matvec_t(&r, &mut od);
        s.matvec_t(&r, &mut os);
        assert_eq!(od, os);
        assert_eq!(d.col_sq_norms(), s.col_sq_norms());
    }

    #[test]
    fn weighted_col_norms_agree_and_match_unweighted() {
        let (d, s) = pair();
        let w = [0.5, 2.0, 1.5];
        for j in 0..3 {
            assert!(
                (d.col_weighted_sq_norm(j, &w) - s.col_weighted_sq_norm(j, &w)).abs() < 1e-14,
                "dense/sparse disagree on column {j}"
            );
        }
        let ones = [1.0, 1.0, 1.0];
        for (j, &nsq) in d.col_sq_norms().iter().enumerate() {
            assert!((d.col_weighted_sq_norm(j, &ones) - nsq).abs() < 1e-14);
        }
    }

    #[test]
    fn subset_matvec_t() {
        let (d, _) = pair();
        let r = [1.0, 1.0, 1.0];
        let mut out = vec![0.0; 2];
        d.matvec_t_subset(&r, &[2, 0], &mut out);
        assert_eq!(out, vec![7.0, 5.0]);
    }

    #[test]
    fn normalize_cols_hits_target() {
        let (mut d, mut s) = pair();
        let sd = d.normalize_cols(3.0_f64.sqrt());
        let ss = s.normalize_cols(3.0_f64.sqrt());
        for (a, b) in sd.iter().zip(ss.iter()) {
            assert!((a - b).abs() < 1e-15);
        }
        for nsq in d.col_sq_norms() {
            assert!((nsq - 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn stored_entries() {
        let (d, s) = pair();
        assert_eq!(d.stored_entries(), 9);
        assert_eq!(s.stored_entries(), 5);
    }

    #[test]
    fn grouped_matvec_t_matches_full_and_permuted() {
        let (d, s) = pair();
        let r = [1.0, -1.0, 2.0];
        let mut full = vec![0.0; 3];
        d.matvec_t(&r, &mut full);
        // identity order fast path
        let mut out = vec![0.0; 3];
        d.matvec_t_groups(&r, &[0, 1, 2], &mut out);
        assert_eq!(out, full);
        // scattered partition order gathers the same dots
        let mut perm = vec![0.0; 3];
        for dd in [&d, &s] {
            dd.matvec_t_groups(&r, &[2, 0, 1], &mut perm);
            assert_eq!(perm, vec![full[2], full[0], full[1]]);
        }
    }

    /// Deterministic LCG fixture (no rand dep): n×p dense + a sparsified
    /// CSC twin, plus a B-column residual panel.
    fn batch_fixture(n: usize, p: usize, b: usize) -> (Design, Design, Vec<f64>) {
        let mut s = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 11) as f64 / (1u64 << 53) as f64) * 2.0 - 1.0
        };
        let mut rows = Vec::with_capacity(n);
        let mut trips = Vec::new();
        for i in 0..n {
            let mut row = Vec::with_capacity(p);
            for j in 0..p {
                let v = next();
                // sparsify the twin but keep values identical where kept
                let keep = (i + 3 * j) % 4 != 0;
                let dv = if keep { v } else { 0.0 };
                row.push(dv);
                if dv != 0.0 {
                    trips.push((i, j, dv));
                }
            }
            rows.push(row);
        }
        let dense = DenseMatrix::from_rows(&rows);
        let sparse = CscMatrix::from_triplets(n, p, &trips);
        let panel: Vec<f64> = (0..n * b).map(|_| next()).collect();
        (Design::Dense(dense), Design::Sparse(sparse), panel)
    }

    #[test]
    fn matmul_t_matches_per_column_matvec_t_bitwise() {
        let (n, p, b) = (23, 19, 5); // odd p exercises the panel remainder
        let (d, s, panel) = batch_fixture(n, p, b);
        for design in [&d, &s] {
            let mut out = vec![0.0; p * b];
            design.matmul_t_threads(&panel, b, &mut out, 1);
            for c in 0..b {
                let mut single = vec![0.0; p];
                design.matvec_t_threads(&panel[c * n..(c + 1) * n], &mut single, 1);
                for j in 0..p {
                    assert_eq!(
                        out[j * b + c].to_bits(),
                        single[j].to_bits(),
                        "multi-RHS ({j},{c}) drifted from single-RHS"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_t_bit_identical_across_thread_counts() {
        let (n, p, b) = (31, 27, 3);
        let (d, s, panel) = batch_fixture(n, p, b);
        for design in [&d, &s] {
            let mut base = vec![0.0; p * b];
            design.matmul_t_threads(&panel, b, &mut base, 1);
            for threads in [2usize, 3, 4, 8] {
                let mut out = vec![0.0; p * b];
                design.matmul_t_threads(&panel, b, &mut out, threads);
                for (k, (a, bb)) in base.iter().zip(out.iter()).enumerate() {
                    assert_eq!(a.to_bits(), bb.to_bits(), "entry {k} at {threads} threads");
                }
            }
        }
    }

    #[test]
    fn panel_axpy_and_weighted_axpy_match_scalar_paths() {
        let (n, p, b) = (17, 9, 4);
        let (d, s, panel) = batch_fixture(n, p, b);
        let coefs = [0.7, 0.0, -1.3, 2.1];
        for design in [&d, &s] {
            let mut got = panel.clone();
            design.col_axpy_panel(3, &coefs, &mut got);
            let mut want = panel.clone();
            for (c, &a) in coefs.iter().enumerate() {
                if a != 0.0 {
                    design.col_axpy(3, a, &mut want[c * n..(c + 1) * n]);
                }
            }
            for (x, y) in got.iter().zip(want.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            // all-ones weights reduce the weighted axpy to (c·x)·1.0 ≡ c·x
            let ones = vec![1.0; n];
            let mut r1 = vec![0.25; n];
            let mut r2 = vec![0.25; n];
            design.col_axpy_weighted(2, -0.9, &ones, &mut r1);
            design.col_axpy(2, -0.9, &mut r2);
            for (x, y) in r1.iter().zip(r2.iter()) {
                assert!((x - y).abs() < 1e-15);
            }
            // zero weights leave rows untouched
            let zeros = vec![0.0; n];
            let mut r3 = vec![0.0; n];
            design.col_axpy_weighted(2, 5.0, &zeros, &mut r3);
            assert!(r3.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn group_sq_norms_sum_column_norms() {
        let (d, s) = pair();
        let sq = d.col_sq_norms();
        // groups {0,2} and {1}
        let cols = [0usize, 2, 1];
        let offsets = [0usize, 2, 3];
        for dd in [&d, &s] {
            let g = dd.group_sq_norms(&cols, &offsets);
            assert!((g[0] - (sq[0] + sq[2])).abs() < 1e-14);
            assert!((g[1] - sq[1]).abs() < 1e-14);
        }
        let reduced = group_reduce_sq(&sq, &cols, &offsets);
        assert_eq!(reduced.len(), 2);
        assert!((reduced[0] - (sq[0] + sq[2])).abs() < 1e-14);
    }
}
