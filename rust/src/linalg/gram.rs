//! Working-set Gram store: the linear-algebra substrate of the Gram-domain
//! inner engine (ISSUE 5 tentpole).
//!
//! For quadratic datafits the inner loop's per-coordinate gradient over a
//! working set `ws` can be maintained from `G_ws = X_wsᵀ X_ws` in O(|ws|)
//! per update instead of two O(n) column passes. [`GramStore`] holds those
//! blocks **incrementally**: every column ever admitted gets a slot, the
//! lower triangle over all slots is kept complete, and admitting a new
//! column computes only its row against the existing slots — when the
//! outer loop doubles the working set, only the new rows/columns are
//! assembled, and blocks computed at one λ of a path sweep are exactly
//! reusable at the next.
//!
//! Kernels (on the PR 2 kernel engine):
//! - dense: a blocked 8-column gather-dot micro-kernel
//!   ([`DenseMatrix::gather_dots_panel`]) over slot chunks;
//! - sparse: CSC column-pair dots — a sorted merge join for short rows
//!   ([`CscMatrix::col_pair_dot`]), a scatter-then-dot pass (densify the
//!   new column once, then one `col_dot` per slot) for long ones.
//!
//! [`GramCache`] wraps a store in a `Mutex` with a **byte budget**: when
//! admitting a working set would exceed it, slots outside the requested
//! set are evicted (a pure repack — surviving pairs are never recomputed)
//! and the eviction is counted. Shared via `Arc` by the coordinator's
//! [`crate::coordinator::cache::DesignEntry`] so path sweeps and CV folds
//! reuse blocks across λ and across jobs.

use super::design::Design;
use super::parallel::{self, KernelPolicy};
use super::simd::{self, Precision, ShadowF32};
use crate::util::lock_or_recover;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Below this many existing slots a sparse row is filled by pairwise
/// merge-join dots; above it the new column is densified once and each
/// pair becomes a plain `col_dot` (cost per pair drops from
/// `nnz_new + nnz_slot` to `nnz_slot`).
const SPARSE_MERGE_MAX_SLOTS: usize = 8;

/// Incremental lower-triangular Gram over every column ever admitted.
///
/// Invariant: `rows[k]` has length `k + 1` and holds
/// `G[k][l] = X_{cols[k]}ᵀ X_{cols[l]}` for every `l ≤ k` — the triangle
/// is always complete, so *any* subset of slots can be gathered without
/// recomputation.
#[derive(Debug, Default)]
pub struct GramStore {
    /// slot → design column
    cols: Vec<usize>,
    /// design column → slot
    slot: HashMap<usize, usize>,
    /// complete lower triangle, `rows[k].len() == k + 1`
    rows: Vec<Vec<f64>>,
    /// densify scratch for sparse designs (zeroed between uses)
    scratch: Vec<f64>,
    /// cumulative stored-entry touches spent assembling blocks
    assembly_flops: u64,
    /// precision of dense off-diagonal assembly ([`Precision::F64`] uses
    /// the gather-dot panel kernel; reduced modes go through an f32
    /// shadow of the design). Diagonals are **always** computed in f64 —
    /// the [`GramStore::check_same_design`] spoof guard compares them
    /// bitwise against `sq_nrm2`. Sparse designs always assemble in f64.
    precision: Precision,
    /// lazily-built f32 design mirror for reduced-precision assembly;
    /// accounted as design-side storage, *not* against the Gram byte
    /// budget (evicting triangle slots could never reclaim it)
    shadow: Option<ShadowF32>,
    /// identity of the design the blocks belong to, recorded at first
    /// admit: (nrows, ncols, stored entries). A store paired with a
    /// different design would silently return wrong gradients; this
    /// turns that into a panic (see [`GramStore::ensure`]).
    design_shape: Option<(usize, usize, usize)>,
}

impl GramStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// A store whose dense off-diagonal blocks are assembled at `prec`.
    pub fn with_precision(prec: Precision) -> Self {
        Self { precision: prec, ..Self::default() }
    }

    /// Assembly precision of dense off-diagonal blocks.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Number of admitted columns.
    pub fn n_slots(&self) -> usize {
        self.cols.len()
    }

    pub fn contains(&self, j: usize) -> bool {
        self.slot.contains_key(&j)
    }

    /// Columns of `ws` not yet admitted.
    pub fn missing(&self, ws: &[usize]) -> usize {
        ws.iter().filter(|j| !self.slot.contains_key(j)).count()
    }

    /// Cumulative assembly work (stored entries touched).
    pub fn assembly_flops(&self) -> u64 {
        self.assembly_flops
    }

    /// Approximate heap footprint (triangle + slot bookkeeping + scratch).
    pub fn bytes(&self) -> usize {
        let entries: usize = self.rows.iter().map(|r| r.len()).sum();
        entries * 8 + self.cols.len() * 64 + self.scratch.len() * 8
    }

    /// Triangle bytes a future state with `slots` admitted columns needs.
    fn triangle_bytes(slots: usize) -> usize {
        slots * (slots + 1) / 2 * 8
    }

    /// Bytes [`GramStore::ensure`] would grow the store by for `ws`.
    pub fn projected_growth_bytes(&self, ws: &[usize]) -> usize {
        let after = self.n_slots() + self.missing(ws);
        Self::triangle_bytes(after).saturating_sub(Self::triangle_bytes(self.n_slots()))
    }

    /// Estimated stored-entry cost of admitting the missing columns of
    /// `ws` (the dispatcher's assembly term; exact for dense designs).
    pub fn projected_assembly_flops(&self, design: &Design, ws: &[usize]) -> f64 {
        let new = self.missing(ws);
        if new == 0 {
            return 0.0;
        }
        let s = self.n_slots();
        // new rows have lengths s+1, s+2, …, s+new
        let pairs = new * s + new * (new + 1) / 2;
        let per_pair = match design {
            Design::Dense(m) => m.nrows() as f64,
            Design::Sparse(m) => (m.nnz() as f64 / m.ncols().max(1) as f64).max(1.0),
        };
        pairs as f64 * per_pair
    }

    /// `G[a][b]` between two slots (either order).
    #[inline]
    fn get(&self, a: usize, b: usize) -> f64 {
        let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
        self.rows[hi][lo]
    }

    /// Admit every missing column of `ws`, computing only the new rows.
    ///
    /// Panics when `design` is not the design the existing blocks were
    /// assembled on (shape/nnz mismatch, or a same-shape design whose
    /// first admitted column has a different norm) — a mispaired store
    /// must fail loudly, not converge to the wrong optimum.
    pub fn ensure(&mut self, design: &Design, ws: &[usize]) {
        self.check_same_design(design);
        for &j in ws {
            if !self.slot.contains_key(&j) {
                self.admit(design, j);
            }
        }
    }

    fn check_same_design(&mut self, design: &Design) {
        let shape = (design.nrows(), design.ncols(), design.stored_entries());
        match self.design_shape {
            None => self.design_shape = Some(shape),
            Some(recorded) => {
                assert_eq!(
                    recorded, shape,
                    "GramStore reused with a different design (recorded {recorded:?})"
                );
                // same-shape spoof guard: recomputing the first admitted
                // slot's diagonal uses the exact summation of `admit`, so
                // on the same design it reproduces bit-for-bit
                if let (Some(&j0), Some(row0)) = (self.cols.first(), self.rows.first()) {
                    let diag = match design {
                        Design::Dense(m) => super::dense::sq_nrm2(m.col(j0)),
                        Design::Sparse(m) => {
                            let (_, vals) = m.col(j0);
                            vals.iter().map(|v| v * v).sum()
                        }
                    };
                    assert!(
                        diag == row0[0],
                        "GramStore reused with a different design: column {j0} norm² \
                         {diag} != recorded {}",
                        row0[0]
                    );
                }
            }
        }
    }

    /// Compute the new slot's row against all existing slots + itself.
    fn admit(&mut self, design: &Design, j: usize) {
        let k = self.cols.len();
        let mut row = vec![0.0; k + 1];
        match design {
            Design::Dense(m) => {
                let r = m.col(j);
                let threads = KernelPolicy::global().threads_for(m.nrows() * (k + 1));
                if self.precision == Precision::F64 {
                    // PANEL-aligned boundaries: a slot's panel membership
                    // (and hence its summation order) depends only on its
                    // position in the row, never on the thread count —
                    // same invariant as the kernel engine's Xᵀr pass
                    let ranges = parallel::even_chunks_aligned(
                        k,
                        parallel::chunk_count(threads),
                        super::dense::PANEL,
                    );
                    let cols = &self.cols;
                    parallel::par_slices(&mut row[..k], &ranges, threads, |_, rng, sub| {
                        m.gather_dots_panel(r, &cols[rng], sub);
                    });
                } else {
                    // reduced precision: one shadow pair-dot per slot. The
                    // reduced dots have a fixed 4-lane order on every ISA,
                    // so the blocks are bit-identical across hosts.
                    let shadow = self.shadow.get_or_insert_with(|| ShadowF32::from_dense(m));
                    let shadow = &*shadow;
                    let rj = shadow.col(j);
                    let prec = self.precision;
                    let ranges = parallel::even_chunks(k, parallel::chunk_count(threads));
                    let cols = &self.cols;
                    parallel::par_slices(&mut row[..k], &ranges, threads, |_, rng, sub| {
                        for (o, &c) in sub.iter_mut().zip(cols[rng].iter()) {
                            *o = simd::reduced_dot(prec, shadow.col(c), rj);
                        }
                    });
                }
                // diagonal always f64: the same-design spoof guard
                // recomputes it bitwise via `sq_nrm2`
                row[k] = super::dense::sq_nrm2(r);
                self.assembly_flops += (m.nrows() * (k + 1)) as u64;
            }
            Design::Sparse(m) => {
                let (j_rows, j_vals) = m.col(j);
                if k <= SPARSE_MERGE_MAX_SLOTS {
                    for (l, &cl) in self.cols.iter().enumerate() {
                        row[l] = m.col_pair_dot(j, cl);
                        self.assembly_flops += (m.col_nnz(j) + m.col_nnz(cl)) as u64;
                    }
                } else {
                    // densify the new column once, then one sparse dot per
                    // existing slot (kernel-engine parallel)
                    self.scratch.resize(m.nrows(), 0.0);
                    for (&i, &v) in j_rows.iter().zip(j_vals.iter()) {
                        self.scratch[i as usize] = v;
                    }
                    let work: usize = self.cols.iter().map(|&c| m.col_nnz(c)).sum();
                    let threads = KernelPolicy::global().threads_for(work);
                    let ranges = parallel::even_chunks(k, parallel::chunk_count(threads));
                    let cols = &self.cols;
                    let scratch = &self.scratch;
                    parallel::par_slices(&mut row[..k], &ranges, threads, |_, rng, sub| {
                        for (o, &c) in sub.iter_mut().zip(cols[rng].iter()) {
                            *o = m.col_dot(c, scratch);
                        }
                    });
                    // un-scatter (keeps the scratch all-zero between uses)
                    for &i in j_rows {
                        self.scratch[i as usize] = 0.0;
                    }
                    self.assembly_flops += (work + 2 * m.col_nnz(j)) as u64;
                }
                row[k] = j_vals.iter().map(|v| v * v).sum();
                self.assembly_flops += m.col_nnz(j) as u64;
            }
        }
        self.rows.push(row);
        self.cols.push(j);
        self.slot.insert(j, k);
    }

    /// Gather the full symmetric `|ws| × |ws|` matrix in `ws` order
    /// (row-major; symmetric, so row `k` *is* column `k` — the contiguous
    /// access the CD update loop wants). Every column of `ws` must be
    /// admitted.
    pub fn gather(&self, ws: &[usize], out: &mut Vec<f64>) {
        let m = ws.len();
        out.clear();
        out.resize(m * m, 0.0);
        let slots: Vec<usize> = ws.iter().map(|j| self.slot[j]).collect();
        for k in 0..m {
            for l in 0..=k {
                let v = self.get(slots[k], slots[l]);
                out[k * m + l] = v;
                out[l * m + k] = v;
            }
        }
    }

    /// Drop every slot whose column is not in `keep`, repacking the
    /// triangle (no pair is recomputed). Returns the number of evicted
    /// slots.
    pub fn compact_to(&mut self, keep: &[usize]) -> usize {
        let keep_set: std::collections::HashSet<usize> = keep.iter().copied().collect();
        let kept: Vec<usize> = (0..self.cols.len())
            .filter(|k| keep_set.contains(&self.cols[*k]))
            .collect();
        let evicted = self.cols.len() - kept.len();
        if evicted == 0 {
            return 0;
        }
        let mut rows = Vec::with_capacity(kept.len());
        let mut cols = Vec::with_capacity(kept.len());
        for (new_k, &old_k) in kept.iter().enumerate() {
            let mut row = vec![0.0; new_k + 1];
            for (new_l, &old_l) in kept[..=new_k].iter().enumerate() {
                row[new_l] = self.get(old_k, old_l);
            }
            rows.push(row);
            cols.push(self.cols[old_k]);
        }
        self.rows = rows;
        self.cols = cols;
        self.slot = self.cols.iter().enumerate().map(|(k, &j)| (j, k)).collect();
        evicted
    }
}

/// Outcome of one [`GramCache::ensure_gather`] call.
#[derive(Clone, Copy, Debug, Default)]
pub struct GramAssembly {
    /// stored-entry touches spent on newly assembled blocks
    pub flops: u64,
    /// slots evicted to respect the byte budget
    pub evicted: usize,
}

/// Default per-cache byte budget (256 MiB of Gram blocks), overridable
/// with the `SKGLM_GRAM_BYTES` env var or [`GramCache::with_budget`].
pub const DEFAULT_GRAM_BUDGET: usize = 256 << 20;

/// Thread-safe, byte-budgeted [`GramStore`] shared across solves (one per
/// coordinator design entry; standalone solves create their own).
pub struct GramCache {
    store: Mutex<GramStore>,
    budget: usize,
    /// dense off-diagonal assembly precision (mirrors the store's; kept
    /// here so callers can read it without taking the store mutex)
    precision: Precision,
    evicted_slots: AtomicUsize,
    /// byte footprint mirrored out of the store after every mutation, so
    /// accounting callers (the scheduler cache's budget enforcement)
    /// never block on the store mutex behind an in-flight assembly
    cur_bytes: AtomicUsize,
}

impl std::fmt::Debug for GramCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = lock_or_recover(&self.store);
        f.debug_struct("GramCache")
            .field("slots", &s.n_slots())
            .field("bytes", &s.bytes())
            .field("budget", &self.budget)
            .finish()
    }
}

impl Default for GramCache {
    fn default() -> Self {
        Self::with_default_budget()
    }
}

impl GramCache {
    pub fn with_budget(budget_bytes: usize) -> Self {
        Self::with_budget_at(budget_bytes, Precision::F64)
    }

    /// A cache whose dense off-diagonal blocks are assembled at `prec`.
    pub fn with_budget_at(budget_bytes: usize, prec: Precision) -> Self {
        Self {
            store: Mutex::new(GramStore::with_precision(prec)),
            budget: budget_bytes.max(1),
            precision: prec,
            evicted_slots: AtomicUsize::new(0),
            cur_bytes: AtomicUsize::new(0),
        }
    }

    /// [`DEFAULT_GRAM_BUDGET`], or the `SKGLM_GRAM_BYTES` override.
    pub fn with_default_budget() -> Self {
        Self::with_default_budget_at(Precision::F64)
    }

    /// [`GramCache::with_default_budget`] at an explicit assembly
    /// precision.
    pub fn with_default_budget_at(prec: Precision) -> Self {
        Self::with_budget_at(
            crate::util::env_byte_budget("SKGLM_GRAM_BYTES", DEFAULT_GRAM_BUDGET),
            prec,
        )
    }

    /// Assembly precision of dense off-diagonal blocks.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Admit `ws` (respecting the byte budget) and gather the symmetric
    /// `|ws| × |ws|` block in `ws` order into `out`.
    ///
    /// If admitting would exceed the budget, slots outside `ws` are
    /// evicted first (pure repack). A working set whose own triangle
    /// exceeds the budget is still served — the solve needs it — and the
    /// next call's eviction pass shrinks the store again.
    pub fn ensure_gather(&self, design: &Design, ws: &[usize], out: &mut Vec<f64>) -> GramAssembly {
        let mut store = lock_or_recover(&self.store);
        let mut asm = GramAssembly::default();
        if store.bytes() + store.projected_growth_bytes(ws) > self.budget {
            asm.evicted = store.compact_to(ws);
            // relaxed: observability counter; the store itself is guarded
            // by the `store` mutex held across this whole assembly
            self.evicted_slots.fetch_add(asm.evicted, Ordering::Relaxed);
        }
        let before = store.assembly_flops();
        store.ensure(design, ws);
        asm.flops = store.assembly_flops() - before;
        store.gather(ws, out);
        self.cur_bytes.store(store.bytes(), Ordering::Relaxed);
        asm
    }

    /// Dispatcher estimate: stored-entry cost of the blocks `ws` still
    /// needs.
    pub fn projected_assembly_flops(&self, design: &Design, ws: &[usize]) -> f64 {
        lock_or_recover(&self.store).projected_assembly_flops(design, ws)
    }

    /// Current byte footprint — served from a mirrored counter, never
    /// from the store mutex (an in-flight assembly must not stall the
    /// scheduler cache's budget accounting).
    pub fn bytes(&self) -> usize {
        self.cur_bytes.load(Ordering::Relaxed)
    }

    pub fn n_slots(&self) -> usize {
        lock_or_recover(&self.store).n_slots()
    }

    /// Cumulative assembly work across every solve sharing this cache.
    pub fn assembly_flops(&self) -> u64 {
        lock_or_recover(&self.store).assembly_flops()
    }

    /// Total slots evicted by budget enforcement.
    pub fn evicted_slots(&self) -> usize {
        self.evicted_slots.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, DenseMatrix};

    fn dense_design() -> Design {
        let data: Vec<f64> = (0..7 * 12).map(|k| ((k * 31 % 17) as f64) - 8.0).collect();
        DenseMatrix::from_col_major(7, 12, data).into()
    }

    fn sparse_design() -> Design {
        let mut trips = Vec::new();
        for j in 0..15 {
            for i in 0..9 {
                if (i * 5 + j * 3) % 4 == 0 {
                    trips.push((i, j, ((i + 2 * j) as f64) * 0.5 - 3.0));
                }
            }
        }
        CscMatrix::from_triplets(9, 15, &trips).into()
    }

    fn reference_pair(d: &Design, a: usize, b: usize) -> f64 {
        let n = d.nrows();
        let mut ca = vec![0.0; n];
        let mut cb = vec![0.0; n];
        d.col_axpy(a, 1.0, &mut ca);
        d.col_axpy(b, 1.0, &mut cb);
        ca.iter().zip(cb.iter()).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn gather_matches_reference_dense_and_sparse() {
        for d in [dense_design(), sparse_design()] {
            let mut store = GramStore::new();
            let ws = [3usize, 0, 7, 5];
            store.ensure(&d, &ws);
            let mut gw = Vec::new();
            store.gather(&ws, &mut gw);
            let m = ws.len();
            for k in 0..m {
                for l in 0..m {
                    let expect = reference_pair(&d, ws[k], ws[l]);
                    assert!(
                        (gw[k * m + l] - expect).abs() < 1e-12,
                        "G[{k}][{l}] = {} vs {expect}",
                        gw[k * m + l]
                    );
                }
            }
        }
    }

    #[test]
    fn growth_is_incremental() {
        let d = dense_design();
        let mut store = GramStore::new();
        store.ensure(&d, &[1, 4]);
        let after_first = store.assembly_flops();
        assert!(after_first > 0);
        // re-ensuring the same set costs nothing
        store.ensure(&d, &[4, 1]);
        assert_eq!(store.assembly_flops(), after_first);
        // doubling the set only pays for the new rows
        store.ensure(&d, &[1, 4, 9, 2]);
        let grown = store.assembly_flops() - after_first;
        // new rows touch n·(3 + 4) entries; a cold rebuild of all four
        // would touch n·(1+2+3+4)
        assert_eq!(grown, 7 * 7);
        assert_eq!(store.n_slots(), 4);
        // the grown store still gathers any subset correctly
        let mut gw = Vec::new();
        store.gather(&[9, 1], &mut gw);
        assert!((gw[0] - reference_pair(&d, 9, 9)).abs() < 1e-12);
        assert!((gw[1] - reference_pair(&d, 9, 1)).abs() < 1e-12);
    }

    #[test]
    fn sparse_merge_and_scatter_paths_agree() {
        let d = sparse_design();
        // small store: merge-join path
        let mut a = GramStore::new();
        a.ensure(&d, &[0, 2, 4]);
        // big store first: scatter path for the late admissions
        let mut b = GramStore::new();
        let all: Vec<usize> = (0..15).collect();
        b.ensure(&d, &all);
        let mut ga = Vec::new();
        let mut gb = Vec::new();
        a.gather(&[0, 2, 4], &mut ga);
        b.gather(&[0, 2, 4], &mut gb);
        for (x, y) in ga.iter().zip(gb.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn compact_keeps_surviving_pairs_without_recompute() {
        let d = dense_design();
        let mut store = GramStore::new();
        store.ensure(&d, &[0, 1, 2, 3, 4, 5]);
        let flops = store.assembly_flops();
        let evicted = store.compact_to(&[1, 4, 5]);
        assert_eq!(evicted, 3);
        assert_eq!(store.n_slots(), 3);
        assert_eq!(store.assembly_flops(), flops, "compaction must not recompute");
        let mut gw = Vec::new();
        store.gather(&[5, 1], &mut gw);
        assert!((gw[1] - reference_pair(&d, 5, 1)).abs() < 1e-12);
    }

    #[test]
    fn cache_budget_evicts_and_counts() {
        let d = dense_design();
        // budget fits only a couple of slots' triangle + bookkeeping
        let cache = GramCache::with_budget(3 * 64 + 6 * 8);
        let mut gw = Vec::new();
        cache.ensure_gather(&d, &[0, 1, 2], &mut gw);
        assert_eq!(cache.n_slots(), 3);
        let asm = cache.ensure_gather(&d, &[8, 9, 10], &mut gw);
        assert!(asm.evicted >= 1, "old slots must be evicted under budget pressure");
        assert_eq!(cache.evicted_slots(), asm.evicted);
        // the gathered block is still correct after eviction
        assert!((gw[0] - reference_pair(&d, 8, 8)).abs() < 1e-12);
        assert!((gw[1] - reference_pair(&d, 8, 9)).abs() < 1e-12);
    }

    #[test]
    fn reduced_precision_blocks_track_f64_with_f64_diagonals() {
        let d = dense_design();
        let ws = [3usize, 0, 7, 5];
        let mut exact = GramStore::new();
        exact.ensure(&d, &ws);
        let mut ge = Vec::new();
        exact.gather(&ws, &mut ge);
        for prec in [Precision::Mixed, Precision::F32] {
            let mut store = GramStore::with_precision(prec);
            assert_eq!(store.precision(), prec);
            store.ensure(&d, &ws);
            let mut gw = Vec::new();
            store.gather(&ws, &mut gw);
            let m = ws.len();
            for k in 0..m {
                for l in 0..m {
                    let (got, want) = (gw[k * m + l], ge[k * m + l]);
                    if k == l {
                        // diagonals stay exact: the same-design guard
                        // compares them bitwise against sq_nrm2
                        assert!(got == want, "{prec:?} diag[{k}] = {got} vs {want}");
                    } else {
                        let scale = want.abs().max(1.0);
                        assert!(
                            (got - want).abs() <= 1e-4 * scale,
                            "{prec:?} G[{k}][{l}] = {got} vs {want}"
                        );
                    }
                }
            }
            // re-ensuring on the same design passes the spoof guard
            store.ensure(&d, &ws);
        }
    }

    #[test]
    fn precision_cache_reports_its_mode() {
        let cache = GramCache::with_default_budget_at(Precision::Mixed);
        assert_eq!(cache.precision(), Precision::Mixed);
        assert_eq!(GramCache::with_default_budget().precision(), Precision::F64);
        let d = dense_design();
        let mut gw = Vec::new();
        cache.ensure_gather(&d, &[1, 4, 9], &mut gw);
        assert_eq!(cache.n_slots(), 3);
    }

    #[test]
    fn projected_assembly_matches_actual_for_dense() {
        let d = dense_design();
        let cache = GramCache::with_default_budget();
        let ws = [2usize, 6, 11];
        let projected = cache.projected_assembly_flops(&d, &ws);
        let mut gw = Vec::new();
        let asm = cache.ensure_gather(&d, &ws, &mut gw);
        assert_eq!(projected, asm.flops as f64);
        // everything admitted: nothing left to project
        assert_eq!(cache.projected_assembly_flops(&d, &ws), 0.0);
    }
}
