//! Runtime-dispatched SIMD micro-kernels and the precision ladder.
//!
//! The repo's hot kernels (`dot`, `axpy`, the blocked `Xᵀr` panels, the
//! multi-RHS batched panel, the gathered Gram-assembly dots) no longer
//! rely on LLVM auto-vectorisation: this module probes the CPU **once**
//! per process ([`isa`]) and dispatches `#[target_feature]`-compiled
//! variants — AVX2 / AVX2+FMA on x86_64, NEON / NEON+FMA on aarch64,
//! scalar everywhere else. The probe is overridable for testing and
//! reproducibility with `--isa` / `SKGLM_ISA`.
//!
//! # Bit-identity contract (per ISA)
//!
//! The PR 2 contract — coefficients are bit-identical across thread
//! counts — is preserved *per ISA* by construction:
//!
//! * `--isa scalar` routes every kernel to the untouched pre-SIMD code
//!   paths in [`super::dense`], so the scalar floor is bit-identical to
//!   the historical kernels.
//! * Every vector `dot` accumulates in the **same fixed 4-lane order**
//!   as the scalar `dense::dot_scalar` (lane ℓ owns indices `4k+ℓ`,
//!   reduced as `(l0+l1)+(l2+l3)`, sequential tail), so the non-FMA
//!   vector dots are **bit-exact** against scalar.
//! * The vector panel kernels produce, for every `(column, rhs)` pair,
//!   exactly the dispatched `dot` of that column — the result depends
//!   only on the column and the right-hand side, never on how the
//!   column space was split across threads or panels. FMA variants fuse
//!   the multiply-add (≤ 1e-12 relative vs scalar) but keep the same
//!   lane order, so they are equally split-invariant.
//!
//! # Precision ladder
//!
//! [`Precision`] selects how the O(n·p) full-design passes are
//! evaluated: `f64` (default), `f32` (f32 storage *and* accumulation)
//! or `mixed` (f32 storage and multiply, f64 accumulation). Reduced
//! precision applies to the *design path only* — scoring scans, Gram
//! assembly off-diagonals and the batched residual panel; inner CD
//! epochs, KKT and certificate checks always run in f64. The reduced
//! kernels have **no FMA variant** and use one fixed 4-lane order, so
//! their results are bit-identical across every ISA. Reduced storage
//! lives in 32-byte-aligned buffers ([`ShadowF32`]) so vector loads are
//! never split across cache lines.

use super::dense::DenseMatrix;
use super::parallel::{self, KernelPolicy};
use std::ops::Range;
use std::sync::atomic::{AtomicU8, Ordering};

/// The instruction-set floor the kernel layer dispatches on. Probed once
/// per process ([`isa`]); `Scalar` is always available and bit-identical
/// to the pre-SIMD kernels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelIsa {
    /// Portable scalar kernels (the historical code paths).
    #[default]
    Scalar,
    /// AVX2 256-bit kernels, separate multiply + add (bit-exact vs scalar).
    Avx2,
    /// AVX2 with fused multiply-add (≤ 1e-12 relative vs scalar).
    Avx2Fma,
    /// NEON 128-bit kernels, separate multiply + add (bit-exact vs scalar).
    Neon,
    /// NEON with fused multiply-add (≤ 1e-12 relative vs scalar).
    NeonFma,
}

impl KernelIsa {
    /// Stable lowercase name (CLI/env/wire spelling).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
            KernelIsa::Avx2Fma => "avx2fma",
            KernelIsa::Neon => "neon",
            KernelIsa::NeonFma => "neonfma",
        }
    }

    /// Parse a concrete ISA name (`"auto"` is handled by the callers
    /// that own the probe).
    pub fn parse(s: &str) -> Option<KernelIsa> {
        match s {
            "scalar" => Some(KernelIsa::Scalar),
            "avx2" => Some(KernelIsa::Avx2),
            "avx2fma" => Some(KernelIsa::Avx2Fma),
            "neon" => Some(KernelIsa::Neon),
            "neonfma" => Some(KernelIsa::NeonFma),
            _ => None,
        }
    }

    /// Whether this variant fuses multiply-adds (then only ≤ 1e-12
    /// relative agreement with scalar is guaranteed, not bit-equality).
    pub fn is_fma(self) -> bool {
        matches!(self, KernelIsa::Avx2Fma | KernelIsa::NeonFma)
    }

    /// Whether the current CPU can execute this variant.
    pub fn supported(self) -> bool {
        match self {
            KernelIsa::Scalar => true,
            KernelIsa::Avx2 | KernelIsa::Avx2Fma => {
                #[cfg(target_arch = "x86_64")]
                {
                    let avx2 = std::arch::is_x86_feature_detected!("avx2");
                    if self == KernelIsa::Avx2 {
                        avx2
                    } else {
                        avx2 && std::arch::is_x86_feature_detected!("fma")
                    }
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            KernelIsa::Neon | KernelIsa::NeonFma => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }
}

/// Best ISA the current CPU supports (ignores the env/CLI override).
pub fn detect() -> KernelIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            if std::arch::is_x86_feature_detected!("fma") {
                return KernelIsa::Avx2Fma;
            }
            return KernelIsa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelIsa::NeonFma;
        }
    }
    KernelIsa::Scalar
}

const ISA_UNSET: u8 = u8::MAX;

/// Process-wide active ISA. One probe per process keeps the dispatch a
/// single atomic load and keeps `GramCache`'s bitwise same-design guard
/// valid (all kernels in a process agree on the ISA).
static ACTIVE_ISA: AtomicU8 = AtomicU8::new(ISA_UNSET);

fn encode(isa: KernelIsa) -> u8 {
    match isa {
        KernelIsa::Scalar => 0,
        KernelIsa::Avx2 => 1,
        KernelIsa::Avx2Fma => 2,
        KernelIsa::Neon => 3,
        KernelIsa::NeonFma => 4,
    }
}

fn decode(v: u8) -> KernelIsa {
    match v {
        1 => KernelIsa::Avx2,
        2 => KernelIsa::Avx2Fma,
        3 => KernelIsa::Neon,
        4 => KernelIsa::NeonFma,
        _ => KernelIsa::Scalar,
    }
}

fn probe() -> KernelIsa {
    if let Ok(v) = std::env::var("SKGLM_ISA") {
        if let Some(req) = KernelIsa::parse(&v) {
            return if req.supported() { req } else { KernelIsa::Scalar };
        }
        // "auto" (or an unvalidated value reaching the env directly)
        // falls through to detection; the CLI and the service validate
        // spellings before they get here.
    }
    detect()
}

/// The active ISA for this process (probing on first use).
pub fn isa() -> KernelIsa {
    let cur = ACTIVE_ISA.load(Ordering::Acquire);
    if cur != ISA_UNSET {
        return decode(cur);
    }
    install(probe())
}

/// Pin the process ISA (first caller wins; unsupported requests clamp to
/// `Scalar`). Returns the ISA actually in effect — callers that pinned
/// after a kernel already ran get the earlier winner back.
pub fn set_isa_override(req: KernelIsa) -> KernelIsa {
    let eff = if req.supported() { req } else { KernelIsa::Scalar };
    install(eff)
}

/// Resolve a CLI/env ISA spelling (including `"auto"`) and pin it.
/// Returns `None` for an unknown name, leaving the probe untouched.
pub fn install_isa(name: &str) -> Option<KernelIsa> {
    if name == "auto" {
        return Some(set_isa_override(detect()));
    }
    KernelIsa::parse(name).map(set_isa_override)
}

fn install(isa: KernelIsa) -> KernelIsa {
    let swapped =
        ACTIVE_ISA.compare_exchange(ISA_UNSET, encode(isa), Ordering::AcqRel, Ordering::Acquire);
    match swapped {
        Ok(_) => isa,
        Err(winner) => decode(winner),
    }
}

/// Numeric precision of the full-design passes (scoring scans, Gram
/// off-diagonals, batched residual panels). KKT and certificate checks
/// always run in f64 regardless of this setting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Everything in f64 (the default; the historical behaviour).
    #[default]
    F64,
    /// f32 design storage, f32 multiply *and* accumulation.
    F32,
    /// f32 design storage and multiply, f64 accumulation.
    Mixed,
}

impl Precision {
    /// Stable lowercase name (CLI/env/wire spelling).
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
            Precision::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            "mixed" => Some(Precision::Mixed),
            _ => None,
        }
    }

    /// Smallest KKT tolerance a solve at this precision can honour: the
    /// reduced-precision gradient is quantised at roughly the storage
    /// epsilon, so the (always-f64) KKT check cannot be driven below
    /// this floor. Solvers clamp `tol` to `max(tol, floor)`.
    pub fn tol_floor(self) -> f64 {
        match self {
            Precision::F64 => 0.0,
            Precision::Mixed => 1e-6,
            Precision::F32 => 5e-4,
        }
    }
}

/// Process default precision (`SKGLM_PRECISION`, set by `--precision`);
/// `SolverOpts::default()` starts from this.
pub fn default_precision() -> Precision {
    std::env::var("SKGLM_PRECISION")
        .ok()
        .and_then(|v| Precision::parse(&v))
        .unwrap_or_default()
}

// ---------------------------------------------------------------------------
// f64 kernels: dispatch wrappers
// ---------------------------------------------------------------------------

/// Dispatched dot product. Non-FMA ISAs are bit-exact against
/// `dense::dot_scalar`; FMA ISAs agree to ≤ 1e-12 relative.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_with(isa(), a, b)
}

/// [`dot`] pinned to a specific ISA (bench/test entry point).
pub fn dot_with(which: KernelIsa, a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    match which {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm is only selected when AVX2 was detected at
        // runtime (probe/override clamp unsupported requests to Scalar).
        KernelIsa::Avx2 => unsafe { x86::dot_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2+FMA were detected at runtime.
        KernelIsa::Avx2Fma => unsafe { x86::dot_fma(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::Neon => unsafe { aarch::dot_neon(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::NeonFma => unsafe { aarch::dot_neonfma(a, b) },
        _ => super::dense::dot_scalar(a, b),
    }
}

/// Dispatched `y += alpha·x` (element-wise, so every non-FMA variant is
/// bit-exact against the scalar loop).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    axpy_with(isa(), alpha, x, y)
}

/// [`axpy`] pinned to a specific ISA.
pub fn axpy_with(which: KernelIsa, alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    match which {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2 was detected at runtime.
        KernelIsa::Avx2 => unsafe { x86::axpy_avx2(alpha, x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2+FMA were detected at runtime.
        KernelIsa::Avx2Fma => unsafe { x86::axpy_fma(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::Neon => unsafe { aarch::axpy_neon(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::NeonFma => unsafe { aarch::axpy_neonfma(alpha, x, y) },
        _ => super::dense::axpy_scalar(alpha, x, y),
    }
}

/// Dispatched blocked `Xᵀr` over a contiguous column range (see
/// [`DenseMatrix::matvec_t_panel`] for the layout contract). Under a
/// vector ISA every output equals the dispatched [`dot`] of its column,
/// so results are independent of the thread/panel split.
pub fn matvec_t_panel(m: &DenseMatrix, r: &[f64], cols: Range<usize>, out: &mut [f64]) {
    matvec_t_panel_with(isa(), m, r, cols, out)
}

/// [`matvec_t_panel`] pinned to a specific ISA.
pub fn matvec_t_panel_with(
    which: KernelIsa,
    m: &DenseMatrix,
    r: &[f64],
    cols: Range<usize>,
    out: &mut [f64],
) {
    assert_eq!(r.len(), m.nrows());
    assert!(cols.end <= m.ncols());
    assert_eq!(out.len(), cols.end - cols.start);
    match which {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2 was detected at runtime.
        KernelIsa::Avx2 => unsafe { x86::matvec_avx2(m, r, cols, out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2+FMA were detected at runtime.
        KernelIsa::Avx2Fma => unsafe { x86::matvec_fma(m, r, cols, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::Neon => unsafe { aarch::matvec_neon(m, r, cols, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::NeonFma => unsafe { aarch::matvec_neonfma(m, r, cols, out) },
        _ => m.matvec_t_panel_scalar(r, cols, out),
    }
}

/// Dispatched multi-RHS panel `Xᵀ R` (see
/// [`DenseMatrix::matmul_t_panel`] for the feature-major layout). Under
/// a vector ISA every `(column, rhs)` output equals the dispatched
/// [`dot`], bit-identical to the single-RHS panel on that rhs alone.
pub fn matmul_t_panel(
    m: &DenseMatrix,
    r: &[f64],
    n_rhs: usize,
    cols: Range<usize>,
    out: &mut [f64],
) {
    matmul_t_panel_with(isa(), m, r, n_rhs, cols, out)
}

/// [`matmul_t_panel`] pinned to a specific ISA.
pub fn matmul_t_panel_with(
    which: KernelIsa,
    m: &DenseMatrix,
    r: &[f64],
    n_rhs: usize,
    cols: Range<usize>,
    out: &mut [f64],
) {
    assert_eq!(r.len(), m.nrows() * n_rhs);
    assert!(cols.end <= m.ncols());
    assert_eq!(out.len(), (cols.end - cols.start) * n_rhs);
    if n_rhs == 1 {
        return matvec_t_panel_with(which, m, r, cols, out);
    }
    if n_rhs == 0 {
        return;
    }
    match which {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2 was detected at runtime.
        KernelIsa::Avx2 => unsafe { x86::matmul_avx2(m, r, n_rhs, cols, out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2+FMA were detected at runtime.
        KernelIsa::Avx2Fma => unsafe { x86::matmul_fma(m, r, n_rhs, cols, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::Neon => unsafe { aarch::matmul_neon(m, r, n_rhs, cols, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::NeonFma => unsafe { aarch::matmul_neonfma(m, r, n_rhs, cols, out) },
        _ => m.matmul_t_panel_scalar(r, n_rhs, cols, out),
    }
}

/// Dispatched gathered dots (the Gram-assembly kernel; see
/// [`DenseMatrix::gather_dots_panel`]). Under a vector ISA every output
/// equals the dispatched [`dot`] of its column, so splitting the column
/// list across threads cannot change the result.
pub fn gather_dots_panel(m: &DenseMatrix, r: &[f64], cols: &[usize], out: &mut [f64]) {
    gather_dots_panel_with(isa(), m, r, cols, out)
}

/// [`gather_dots_panel`] pinned to a specific ISA.
pub fn gather_dots_panel_with(
    which: KernelIsa,
    m: &DenseMatrix,
    r: &[f64],
    cols: &[usize],
    out: &mut [f64],
) {
    assert_eq!(r.len(), m.nrows());
    assert_eq!(out.len(), cols.len());
    match which {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2 was detected at runtime.
        KernelIsa::Avx2 => unsafe { x86::gather_avx2(m, r, cols, out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2+FMA were detected at runtime.
        KernelIsa::Avx2Fma => unsafe { x86::gather_fma(m, r, cols, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::Neon => unsafe { aarch::gather_neon(m, r, cols, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::NeonFma => unsafe { aarch::gather_neonfma(m, r, cols, out) },
        _ => m.gather_dots_panel_scalar(r, cols, out),
    }
}

// ---------------------------------------------------------------------------
// Reduced-precision kernels (no FMA variants: bit-identical across ISAs)
// ---------------------------------------------------------------------------

/// Fixed-order scalar reference for the `mixed` dot: products rounded
/// to f32, widened, accumulated in f64 over the same 4 lanes the vector
/// kernels use. Every ISA reproduces this bit-for-bit.
pub fn dot_mixed_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += (a[i] * b[i]) as f64;
        s1 += (a[i + 1] * b[i + 1]) as f64;
        s2 += (a[i + 2] * b[i + 2]) as f64;
        s3 += (a[i + 3] * b[i + 3]) as f64;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += (a[i] * b[i]) as f64;
    }
    s
}

/// Fixed-order scalar reference for the `f32` dot: f32 multiply *and*
/// accumulation over 4 lanes, widened once at the end. Every ISA
/// reproduces this bit-for-bit.
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s as f64
}

/// Dispatched `mixed` dot (f32 multiply, f64 accumulate).
#[inline]
pub fn dot_mixed(a: &[f32], b: &[f32]) -> f64 {
    dot_mixed_with(isa(), a, b)
}

/// [`dot_mixed`] pinned to a specific ISA.
pub fn dot_mixed_with(which: KernelIsa, a: &[f32], b: &[f32]) -> f64 {
    match which {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2 (hence AVX) was detected at runtime.
        KernelIsa::Avx2 | KernelIsa::Avx2Fma => unsafe { x86::dot_mixed_avx(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::Neon | KernelIsa::NeonFma => unsafe { aarch::dot_mixed_neon(a, b) },
        _ => dot_mixed_scalar(a, b),
    }
}

/// Dispatched `f32` dot (f32 multiply and accumulate).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    dot_f32_with(isa(), a, b)
}

/// [`dot_f32`] pinned to a specific ISA.
pub fn dot_f32_with(which: KernelIsa, a: &[f32], b: &[f32]) -> f64 {
    match which {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: only selected when AVX2 was detected at runtime.
        KernelIsa::Avx2 | KernelIsa::Avx2Fma => unsafe { x86::dot_f32_sse(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: only selected when NEON was detected at runtime.
        KernelIsa::Neon | KernelIsa::NeonFma => unsafe { aarch::dot_f32_neon(a, b) },
        _ => dot_f32_scalar(a, b),
    }
}

/// Dispatched reduced-precision dot for `prec` (which must not be
/// [`Precision::F64`] — that path never builds an f32 shadow).
#[inline]
pub fn reduced_dot(prec: Precision, a: &[f32], b: &[f32]) -> f64 {
    debug_assert_ne!(prec, Precision::F64);
    match prec {
        Precision::F32 => dot_f32(a, b),
        _ => dot_mixed(a, b),
    }
}

// ---------------------------------------------------------------------------
// 32-byte-aligned f32 design shadow
// ---------------------------------------------------------------------------

/// 32-byte-aligned f32 copy of a dense design: column-major, each
/// column padded to a multiple of 8 f32 so every column starts on a
/// 32-byte boundary and vector loads are never split.
#[derive(Clone, Debug)]
pub struct ShadowF32 {
    n: usize,
    p: usize,
    stride: usize,
    off: usize,
    data: Vec<f32>,
}

impl ShadowF32 {
    /// Round-to-f32 copy of `m` (one pass over the design).
    pub fn from_dense(m: &DenseMatrix) -> Self {
        let n = m.nrows();
        let p = m.ncols();
        let stride = n.div_ceil(8) * 8;
        // over-allocate 7 elements so the aligned window always fits
        let data = vec![0.0f32; stride * p + 7];
        let off = data.as_ptr().align_offset(32);
        debug_assert!(off <= 7);
        let mut s = Self { n, p, stride, off, data };
        for j in 0..p {
            let col = m.col(j);
            let base = s.off + j * s.stride;
            for (i, &v) in col.iter().enumerate() {
                s.data[base + i] = v as f32;
            }
        }
        s
    }

    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// 32-byte-aligned column slice (length `n`, padding excluded).
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        debug_assert!(j < self.p);
        let base = self.off + j * self.stride;
        &self.data[base..base + self.n]
    }

    /// Heap bytes held by the shadow (budget accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Round a f64 slice into a reusable f32 scratch buffer.
pub fn to_f32(src: &[f64], dst: &mut Vec<f32>) {
    dst.clear();
    dst.extend(src.iter().map(|&v| v as f32));
}

/// Reduced-precision scoring scan: `out[j] = scale · dot_prec(col j,
/// r32)` over every shadow column, parallelised like
/// `Design::matvec_t` (per-column results are split-invariant, so no
/// panel alignment is needed).
pub fn shadow_matvec_t(s: &ShadowF32, r32: &[f32], prec: Precision, scale: f64, out: &mut [f64]) {
    assert_eq!(r32.len(), s.n);
    assert_eq!(out.len(), s.p);
    let threads = KernelPolicy::global().threads_for(s.n * s.p);
    let ranges = parallel::even_chunks(s.p, parallel::chunk_count(threads));
    parallel::par_slices(out, &ranges, threads, |_, cols, sub| {
        for (o, j) in cols.enumerate() {
            sub[o] = scale * reduced_dot(prec, s.col(j), r32);
        }
    });
}

/// Reduced-precision multi-RHS panel scan: feature-major output
/// (`out[j·n_rhs + c]`), mirroring `Design::matmul_t`.
pub fn shadow_matmul_t(
    s: &ShadowF32,
    panel32: &[f32],
    n_rhs: usize,
    prec: Precision,
    out: &mut [f64],
) {
    assert_eq!(panel32.len(), s.n * n_rhs);
    assert_eq!(out.len(), s.p * n_rhs);
    if n_rhs == 0 {
        return;
    }
    let threads = KernelPolicy::global().threads_for(s.n * s.p * n_rhs);
    let col_ranges = parallel::even_chunks(s.p, parallel::chunk_count(threads));
    let out_ranges: Vec<Range<usize>> = col_ranges
        .iter()
        .map(|r| r.start * n_rhs..r.end * n_rhs)
        .collect();
    parallel::par_slices(out, &out_ranges, threads, |k, _, sub| {
        let cols = col_ranges[k].clone();
        for (o, j) in cols.enumerate() {
            let cj = s.col(j);
            for c in 0..n_rhs {
                sub[o * n_rhs + c] = reduced_dot(prec, cj, &panel32[c * s.n..(c + 1) * s.n]);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// x86_64 kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::super::dense::DenseMatrix;
    use core::arch::x86_64::*;
    use std::ops::Range;

    // SAFETY: pure register arithmetic; caller must be an AVX2 context.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn madd_mul(a: __m256d, b: __m256d, acc: __m256d) -> __m256d {
        _mm256_add_pd(acc, _mm256_mul_pd(a, b))
    }

    // SAFETY: pure register arithmetic; caller must be an AVX2+FMA context.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn madd_fma(a: __m256d, b: __m256d, acc: __m256d) -> __m256d {
        _mm256_fmadd_pd(a, b, acc)
    }

    // Reduces in the scalar `dot` lane order: (l0+l1)+(l2+l3).
    // SAFETY: pure register arithmetic; caller must be an AVX2 context.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn reduce4(v: __m256d) -> f64 {
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), v);
        (l[0] + l[1]) + (l[2] + l[3])
    }

    macro_rules! stamp_f64_kernels {
        ($feat:literal, $madd:ident, $dot:ident, $axpy:ident, $cols4:ident,
         $matvec:ident, $matmul:ident, $gather:ident) => {
            // The dispatcher only selects this variant after runtime
            // feature detection.
            // SAFETY: `$feat` is available; loads stay inside the
            // slice bounds (chunks·4 ≤ n, tail is scalar).
            #[target_feature(enable = $feat)]
            pub(super) unsafe fn $dot(a: &[f64], b: &[f64]) -> f64 {
                let n = a.len();
                let chunks = n / 4;
                let mut acc = _mm256_setzero_pd();
                for k in 0..chunks {
                    let i = 4 * k;
                    let av = _mm256_loadu_pd(a.as_ptr().add(i));
                    let bv = _mm256_loadu_pd(b.as_ptr().add(i));
                    acc = $madd(av, bv, acc);
                }
                let mut s = reduce4(acc);
                for i in 4 * chunks..n {
                    s += a[i] * b[i];
                }
                s
            }

            // SAFETY: `$feat` is available (runtime-detected before
            // dispatch); loads/stores stay inside the slice bounds.
            #[target_feature(enable = $feat)]
            pub(super) unsafe fn $axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
                let n = y.len();
                let chunks = n / 4;
                let av = _mm256_set1_pd(alpha);
                for k in 0..chunks {
                    let i = 4 * k;
                    let xv = _mm256_loadu_pd(x.as_ptr().add(i));
                    let yv = _mm256_loadu_pd(y.as_ptr().add(i));
                    _mm256_storeu_pd(y.as_mut_ptr().add(i), $madd(xv, av, yv));
                }
                for i in 4 * chunks..n {
                    y[i] += alpha * x[i];
                }
            }

            // Four columns share each loaded r vector; each lane order
            // matches `$dot` exactly, so s[q] == $dot(c[q], r) bitwise.
            // SAFETY: `$feat` is available (runtime-detected before
            // dispatch); every column has length n = r.len().
            #[target_feature(enable = $feat)]
            unsafe fn $cols4(c: [&[f64]; 4], r: &[f64]) -> [f64; 4] {
                let n = r.len();
                let chunks = n / 4;
                let mut a0 = _mm256_setzero_pd();
                let mut a1 = _mm256_setzero_pd();
                let mut a2 = _mm256_setzero_pd();
                let mut a3 = _mm256_setzero_pd();
                for k in 0..chunks {
                    let i = 4 * k;
                    let rv = _mm256_loadu_pd(r.as_ptr().add(i));
                    a0 = $madd(_mm256_loadu_pd(c[0].as_ptr().add(i)), rv, a0);
                    a1 = $madd(_mm256_loadu_pd(c[1].as_ptr().add(i)), rv, a1);
                    a2 = $madd(_mm256_loadu_pd(c[2].as_ptr().add(i)), rv, a2);
                    a3 = $madd(_mm256_loadu_pd(c[3].as_ptr().add(i)), rv, a3);
                }
                let mut s = [reduce4(a0), reduce4(a1), reduce4(a2), reduce4(a3)];
                for i in 4 * chunks..n {
                    let ri = r[i];
                    s[0] += c[0][i] * ri;
                    s[1] += c[1][i] * ri;
                    s[2] += c[2][i] * ri;
                    s[3] += c[3][i] * ri;
                }
                s
            }

            // SAFETY: `$feat` is available (runtime-detected before
            // dispatch); bounds are asserted by the dispatch wrapper.
            #[target_feature(enable = $feat)]
            pub(super) unsafe fn $matvec(
                m: &DenseMatrix,
                r: &[f64],
                cols: Range<usize>,
                out: &mut [f64],
            ) {
                let mut j = cols.start;
                let mut o = 0usize;
                while j + 4 <= cols.end {
                    let s = $cols4([m.col(j), m.col(j + 1), m.col(j + 2), m.col(j + 3)], r);
                    out[o..o + 4].copy_from_slice(&s);
                    j += 4;
                    o += 4;
                }
                while j < cols.end {
                    out[o] = $dot(m.col(j), r);
                    j += 1;
                    o += 1;
                }
            }

            // 4 columns × 2 right-hand sides per inner block: each
            // design vector load is reused across both rhs and each rhs
            // load across 4 columns, while every (j, c) accumulator
            // still steps i in the `$dot` lane order.
            // SAFETY: `$feat` is available (runtime-detected before
            // dispatch); bounds are asserted by the dispatch wrapper.
            #[target_feature(enable = $feat)]
            pub(super) unsafe fn $matmul(
                m: &DenseMatrix,
                r: &[f64],
                n_rhs: usize,
                cols: Range<usize>,
                out: &mut [f64],
            ) {
                let n = m.nrows();
                let chunks = n / 4;
                let mut j = cols.start;
                let mut o = 0usize;
                while j + 4 <= cols.end {
                    let c = [m.col(j), m.col(j + 1), m.col(j + 2), m.col(j + 3)];
                    let mut cc = 0usize;
                    while cc + 2 <= n_rhs {
                        let r0 = &r[cc * n..(cc + 1) * n];
                        let r1 = &r[(cc + 1) * n..(cc + 2) * n];
                        let mut acc = [_mm256_setzero_pd(); 8];
                        for k in 0..chunks {
                            let i = 4 * k;
                            let rv0 = _mm256_loadu_pd(r0.as_ptr().add(i));
                            let rv1 = _mm256_loadu_pd(r1.as_ptr().add(i));
                            for q in 0..4 {
                                let xv = _mm256_loadu_pd(c[q].as_ptr().add(i));
                                acc[2 * q] = $madd(xv, rv0, acc[2 * q]);
                                acc[2 * q + 1] = $madd(xv, rv1, acc[2 * q + 1]);
                            }
                        }
                        for q in 0..4 {
                            let mut s0 = reduce4(acc[2 * q]);
                            let mut s1 = reduce4(acc[2 * q + 1]);
                            for i in 4 * chunks..n {
                                s0 += c[q][i] * r0[i];
                                s1 += c[q][i] * r1[i];
                            }
                            out[(o + q) * n_rhs + cc] = s0;
                            out[(o + q) * n_rhs + cc + 1] = s1;
                        }
                        cc += 2;
                    }
                    if cc < n_rhs {
                        let s = $cols4(c, &r[cc * n..(cc + 1) * n]);
                        for q in 0..4 {
                            out[(o + q) * n_rhs + cc] = s[q];
                        }
                    }
                    j += 4;
                    o += 4;
                }
                while j < cols.end {
                    let col = m.col(j);
                    for cc in 0..n_rhs {
                        out[o * n_rhs + cc] = $dot(col, &r[cc * n..(cc + 1) * n]);
                    }
                    j += 1;
                    o += 1;
                }
            }

            // Every index in `cols` is a valid column (asserted by the
            // dispatch wrapper along with the slice bounds).
            // SAFETY: `$feat` is available (runtime-detected before
            // dispatch); bounds are asserted by the dispatch wrapper.
            #[target_feature(enable = $feat)]
            pub(super) unsafe fn $gather(
                m: &DenseMatrix,
                r: &[f64],
                cols: &[usize],
                out: &mut [f64],
            ) {
                let mut k = 0usize;
                while k + 4 <= cols.len() {
                    let s = $cols4(
                        [
                            m.col(cols[k]),
                            m.col(cols[k + 1]),
                            m.col(cols[k + 2]),
                            m.col(cols[k + 3]),
                        ],
                        r,
                    );
                    out[k..k + 4].copy_from_slice(&s);
                    k += 4;
                }
                while k < cols.len() {
                    out[k] = $dot(m.col(cols[k]), r);
                    k += 1;
                }
            }
        };
    }

    #[rustfmt::skip]
    stamp_f64_kernels!(
        "avx2", madd_mul, dot_avx2, axpy_avx2, cols4_avx2, matvec_avx2, matmul_avx2, gather_avx2
    );
    #[rustfmt::skip]
    stamp_f64_kernels!(
        "avx2,fma", madd_fma, dot_fma, axpy_fma, cols4_fma, matvec_fma, matmul_fma, gather_fma
    );

    // Lane order matches `dot_mixed_scalar`: f32 products widened and
    // accumulated in 4 f64 lanes, reduced (l0+l1)+(l2+l3).
    // SAFETY: AVX is available whenever the dispatcher selects an AVX2
    // variant (runtime-detected); loads stay inside the slice bounds.
    #[target_feature(enable = "avx")]
    pub(super) unsafe fn dot_mixed_avx(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for k in 0..chunks {
            let i = 4 * k;
            let av = _mm_loadu_ps(a.as_ptr().add(i));
            let bv = _mm_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_add_pd(acc, _mm256_cvtps_pd(_mm_mul_ps(av, bv)));
        }
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), acc);
        let mut s = (l[0] + l[1]) + (l[2] + l[3]);
        for i in 4 * chunks..n {
            s += (a[i] * b[i]) as f64;
        }
        s
    }

    // Lane order matches `dot_f32_scalar` (f32 accumulation, widened
    // once at the end).
    // SAFETY: SSE is x86_64 baseline, but this is only dispatched from
    // AVX2-detected contexts anyway; loads stay in the slice bounds.
    #[target_feature(enable = "sse")]
    pub(super) unsafe fn dot_f32_sse(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm_setzero_ps();
        for k in 0..chunks {
            let i = 4 * k;
            let av = _mm_loadu_ps(a.as_ptr().add(i));
            let bv = _mm_loadu_ps(b.as_ptr().add(i));
            acc = _mm_add_ps(acc, _mm_mul_ps(av, bv));
        }
        let mut l = [0.0f32; 4];
        _mm_storeu_ps(l.as_mut_ptr(), acc);
        let mut s = (l[0] + l[1]) + (l[2] + l[3]);
        for i in 4 * chunks..n {
            s += a[i] * b[i];
        }
        s as f64
    }
}

// ---------------------------------------------------------------------------
// aarch64 kernels (two 128-bit accumulator pairs emulate the 4-lane order)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod aarch {
    use super::super::dense::DenseMatrix;
    use core::arch::aarch64::*;
    use std::ops::Range;

    // SAFETY: pure register arithmetic; caller must be a NEON context.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn madd_mul(a: float64x2_t, b: float64x2_t, acc: float64x2_t) -> float64x2_t {
        vaddq_f64(acc, vmulq_f64(a, b))
    }

    // SAFETY: pure register arithmetic; caller must be a NEON context.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn madd_fma(a: float64x2_t, b: float64x2_t, acc: float64x2_t) -> float64x2_t {
        vfmaq_f64(acc, a, b)
    }

    macro_rules! stamp_f64_kernels {
        ($madd:ident, $dot:ident, $axpy:ident, $cols4:ident,
         $matvec:ident, $matmul:ident, $gather:ident) => {
            // acc01/acc23 hold the scalar `dot` lanes (0,1)/(2,3);
            // vaddvq_f64 sums each pair, giving (s0+s1)+(s2+s3). The
            // dispatcher only selects this after feature detection.
            // SAFETY: NEON is available; loads stay inside the slice
            // bounds (chunks·4 ≤ n, tail is scalar).
            #[target_feature(enable = "neon")]
            pub(super) unsafe fn $dot(a: &[f64], b: &[f64]) -> f64 {
                let n = a.len();
                let chunks = n / 4;
                let mut a01 = vdupq_n_f64(0.0);
                let mut a23 = vdupq_n_f64(0.0);
                for k in 0..chunks {
                    let i = 4 * k;
                    a01 = $madd(vld1q_f64(a.as_ptr().add(i)), vld1q_f64(b.as_ptr().add(i)), a01);
                    a23 = $madd(
                        vld1q_f64(a.as_ptr().add(i + 2)),
                        vld1q_f64(b.as_ptr().add(i + 2)),
                        a23,
                    );
                }
                let mut s = vaddvq_f64(a01) + vaddvq_f64(a23);
                for i in 4 * chunks..n {
                    s += a[i] * b[i];
                }
                s
            }

            // SAFETY: NEON is available (runtime-detected before
            // dispatch); loads/stores stay inside the slice bounds.
            #[target_feature(enable = "neon")]
            pub(super) unsafe fn $axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
                let n = y.len();
                let chunks = n / 2;
                let av = vdupq_n_f64(alpha);
                for k in 0..chunks {
                    let i = 2 * k;
                    let xv = vld1q_f64(x.as_ptr().add(i));
                    let yv = vld1q_f64(y.as_ptr().add(i));
                    vst1q_f64(y.as_mut_ptr().add(i), $madd(xv, av, yv));
                }
                for i in 2 * chunks..n {
                    y[i] += alpha * x[i];
                }
            }

            // Lane order matches `$dot`, so s[q] == $dot(c[q], r)
            // bitwise.
            // SAFETY: NEON is available (runtime-detected before
            // dispatch); every column has length n = r.len().
            #[target_feature(enable = "neon")]
            unsafe fn $cols4(c: [&[f64]; 4], r: &[f64]) -> [f64; 4] {
                let n = r.len();
                let chunks = n / 4;
                let mut acc = [vdupq_n_f64(0.0); 8];
                for k in 0..chunks {
                    let i = 4 * k;
                    let r01 = vld1q_f64(r.as_ptr().add(i));
                    let r23 = vld1q_f64(r.as_ptr().add(i + 2));
                    for q in 0..4 {
                        acc[2 * q] = $madd(vld1q_f64(c[q].as_ptr().add(i)), r01, acc[2 * q]);
                        acc[2 * q + 1] =
                            $madd(vld1q_f64(c[q].as_ptr().add(i + 2)), r23, acc[2 * q + 1]);
                    }
                }
                let mut s = [0.0f64; 4];
                for q in 0..4 {
                    s[q] = vaddvq_f64(acc[2 * q]) + vaddvq_f64(acc[2 * q + 1]);
                }
                for i in 4 * chunks..n {
                    let ri = r[i];
                    for q in 0..4 {
                        s[q] += c[q][i] * ri;
                    }
                }
                s
            }

            // SAFETY: NEON is available (runtime-detected before
            // dispatch); bounds are asserted by the dispatch wrapper.
            #[target_feature(enable = "neon")]
            pub(super) unsafe fn $matvec(
                m: &DenseMatrix,
                r: &[f64],
                cols: Range<usize>,
                out: &mut [f64],
            ) {
                let mut j = cols.start;
                let mut o = 0usize;
                while j + 4 <= cols.end {
                    let s = $cols4([m.col(j), m.col(j + 1), m.col(j + 2), m.col(j + 3)], r);
                    out[o..o + 4].copy_from_slice(&s);
                    j += 4;
                    o += 4;
                }
                while j < cols.end {
                    out[o] = $dot(m.col(j), r);
                    j += 1;
                    o += 1;
                }
            }

            // SAFETY: NEON is available (runtime-detected before
            // dispatch); bounds are asserted by the dispatch wrapper.
            #[target_feature(enable = "neon")]
            pub(super) unsafe fn $matmul(
                m: &DenseMatrix,
                r: &[f64],
                n_rhs: usize,
                cols: Range<usize>,
                out: &mut [f64],
            ) {
                let n = m.nrows();
                let mut j = cols.start;
                let mut o = 0usize;
                while j + 4 <= cols.end {
                    let c = [m.col(j), m.col(j + 1), m.col(j + 2), m.col(j + 3)];
                    for cc in 0..n_rhs {
                        let s = $cols4(c, &r[cc * n..(cc + 1) * n]);
                        for q in 0..4 {
                            out[(o + q) * n_rhs + cc] = s[q];
                        }
                    }
                    j += 4;
                    o += 4;
                }
                while j < cols.end {
                    let col = m.col(j);
                    for cc in 0..n_rhs {
                        out[o * n_rhs + cc] = $dot(col, &r[cc * n..(cc + 1) * n]);
                    }
                    j += 1;
                    o += 1;
                }
            }

            // Every index in `cols` is a valid column (asserted by the
            // dispatch wrapper along with the slice bounds).
            // SAFETY: NEON is available (runtime-detected before
            // dispatch); bounds are asserted by the dispatch wrapper.
            #[target_feature(enable = "neon")]
            pub(super) unsafe fn $gather(
                m: &DenseMatrix,
                r: &[f64],
                cols: &[usize],
                out: &mut [f64],
            ) {
                let mut k = 0usize;
                while k + 4 <= cols.len() {
                    let s = $cols4(
                        [
                            m.col(cols[k]),
                            m.col(cols[k + 1]),
                            m.col(cols[k + 2]),
                            m.col(cols[k + 3]),
                        ],
                        r,
                    );
                    out[k..k + 4].copy_from_slice(&s);
                    k += 4;
                }
                while k < cols.len() {
                    out[k] = $dot(m.col(cols[k]), r);
                    k += 1;
                }
            }
        };
    }

    #[rustfmt::skip]
    stamp_f64_kernels!(
        madd_mul, dot_neon, axpy_neon, cols4_neon, matvec_neon, matmul_neon, gather_neon
    );
    #[rustfmt::skip]
    stamp_f64_kernels!(
        madd_fma, dot_neonfma, axpy_neonfma, cols4_neonfma, matvec_neonfma, matmul_neonfma,
        gather_neonfma
    );

    // f32 products are widened to the scalar reference's (0,1)/(2,3)
    // f64 lanes, so the result is bit-identical to `dot_mixed_scalar`.
    // SAFETY: NEON is available (runtime-detected before dispatch);
    // loads stay inside the slice bounds.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_mixed_neon(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let mut a01 = vdupq_n_f64(0.0);
        let mut a23 = vdupq_n_f64(0.0);
        for k in 0..chunks {
            let i = 4 * k;
            let prod = vmulq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            a01 = vaddq_f64(a01, vcvt_f64_f32(vget_low_f32(prod)));
            a23 = vaddq_f64(a23, vcvt_high_f64_f32(prod));
        }
        let mut s = vaddvq_f64(a01) + vaddvq_f64(a23);
        for i in 4 * chunks..n {
            s += (a[i] * b[i]) as f64;
        }
        s
    }

    // Lane order matches `dot_f32_scalar` (explicit lane extraction,
    // no vaddv tree).
    // SAFETY: NEON is available (runtime-detected before dispatch);
    // loads stay inside the slice bounds.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_f32_neon(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 4;
        let mut acc = vdupq_n_f32(0.0);
        for k in 0..chunks {
            let i = 4 * k;
            acc = vaddq_f32(
                acc,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i))),
            );
        }
        let mut s = (vgetq_lane_f32::<0>(acc) + vgetq_lane_f32::<1>(acc))
            + (vgetq_lane_f32::<2>(acc) + vgetq_lane_f32::<3>(acc));
        for i in 4 * chunks..n {
            s += a[i] * b[i];
        }
        s as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state % 2000) as f64 / 100.0 - 10.0
        };
        let a: Vec<f64> = (0..n).map(|_| next()).collect();
        let b: Vec<f64> = (0..n).map(|_| next()).collect();
        (a, b)
    }

    fn supported_isas() -> Vec<KernelIsa> {
        [
            KernelIsa::Scalar,
            KernelIsa::Avx2,
            KernelIsa::Avx2Fma,
            KernelIsa::Neon,
            KernelIsa::NeonFma,
        ]
        .into_iter()
        .filter(|i| i.supported())
        .collect()
    }

    #[test]
    fn isa_names_round_trip() {
        for isa in [
            KernelIsa::Scalar,
            KernelIsa::Avx2,
            KernelIsa::Avx2Fma,
            KernelIsa::Neon,
            KernelIsa::NeonFma,
        ] {
            assert_eq!(KernelIsa::parse(isa.as_str()), Some(isa));
        }
        assert_eq!(KernelIsa::parse("sse9"), None);
        assert_eq!(KernelIsa::parse("auto"), None);
    }

    #[test]
    fn precision_names_and_floors() {
        for p in [Precision::F64, Precision::F32, Precision::Mixed] {
            assert_eq!(Precision::parse(p.as_str()), Some(p));
        }
        assert_eq!(Precision::parse("half"), None);
        assert_eq!(Precision::F64.tol_floor(), 0.0);
        assert!(Precision::Mixed.tol_floor() < Precision::F32.tol_floor());
    }

    #[test]
    fn detected_isa_is_supported_and_active_isa_is_stable() {
        assert!(detect().supported());
        let a = isa();
        assert!(a.supported());
        // once probed, overrides cannot change the process ISA
        assert_eq!(set_isa_override(KernelIsa::Scalar), a);
        assert_eq!(isa(), a);
    }

    #[test]
    fn dot_matches_scalar_on_every_supported_isa() {
        for n in [0usize, 1, 3, 4, 7, 8, 64, 129] {
            let (a, b) = vecs(n, n as u64 + 1);
            let reference = crate::linalg::dense::dot_scalar(&a, &b);
            for which in supported_isas() {
                let got = dot_with(which, &a, &b);
                if which.is_fma() {
                    let scale = reference.abs().max(1.0);
                    assert!(
                        (got - reference).abs() <= 1e-12 * scale,
                        "{which:?} n={n}: {got} vs {reference}"
                    );
                } else {
                    assert_eq!(
                        got.to_bits(),
                        reference.to_bits(),
                        "{which:?} n={n}: {got} vs {reference}"
                    );
                }
            }
        }
    }

    #[test]
    fn axpy_matches_scalar_on_every_supported_isa() {
        for n in [0usize, 1, 5, 8, 65] {
            let (x, y0) = vecs(n, 7 + n as u64);
            for which in supported_isas() {
                let mut want = y0.clone();
                crate::linalg::dense::axpy_scalar(0.37, &x, &mut want);
                let mut got = y0.clone();
                axpy_with(which, 0.37, &x, &mut got);
                for i in 0..n {
                    if which.is_fma() {
                        assert!((got[i] - want[i]).abs() <= 1e-12 * want[i].abs().max(1.0));
                    } else {
                        assert_eq!(got[i].to_bits(), want[i].to_bits(), "{which:?} n={n} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn panel_kernels_equal_their_own_isa_dot_bitwise() {
        // vector panel outputs must equal the same-ISA dot per column —
        // the split-invariance contract (scalar keeps its historical
        // 8-wide panel order, checked separately below)
        for (n, p) in [(5usize, 7usize), (6, 8), (13, 9), (3, 17), (32, 12)] {
            let data: Vec<f64> = (0..n * p).map(|k| ((k * 37 % 19) as f64) - 9.0).collect();
            let m = DenseMatrix::from_col_major(n, p, data);
            let (r, _) = vecs(n, 31 + (n * p) as u64);
            for which in supported_isas() {
                if which == KernelIsa::Scalar {
                    continue;
                }
                let mut panel = vec![0.0; p];
                matvec_t_panel_with(which, &m, &r, 0..p, &mut panel);
                for j in 0..p {
                    let want = dot_with(which, m.col(j), &r);
                    assert_eq!(panel[j].to_bits(), want.to_bits(), "{which:?} matvec j={j}");
                }
                let cols: Vec<usize> = (0..p).rev().collect();
                let mut gath = vec![0.0; p];
                gather_dots_panel_with(which, &m, &r, &cols, &mut gath);
                for (k, &j) in cols.iter().enumerate() {
                    let want = dot_with(which, m.col(j), &r);
                    assert_eq!(gath[k].to_bits(), want.to_bits(), "{which:?} gather j={j}");
                }
                for b in [2usize, 3, 5] {
                    let (panelr, _) = vecs(n * b, 91 + b as u64);
                    let mut mm = vec![0.0; p * b];
                    matmul_t_panel_with(which, &m, &panelr, b, 0..p, &mut mm);
                    for j in 0..p {
                        for c in 0..b {
                            let want = dot_with(which, m.col(j), &panelr[c * n..(c + 1) * n]);
                            assert_eq!(
                                mm[j * b + c].to_bits(),
                                want.to_bits(),
                                "{which:?} matmul j={j} c={c} b={b}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn scalar_dispatch_is_bit_identical_to_legacy_kernels() {
        let (n, p) = (11usize, 19usize);
        let data: Vec<f64> = (0..n * p).map(|k| ((k * 13 % 23) as f64) - 11.0).collect();
        let m = DenseMatrix::from_col_major(n, p, data);
        let (r, _) = vecs(n, 5);
        let mut legacy = vec![0.0; p];
        m.matvec_t_panel_scalar(&r, 0..p, &mut legacy);
        let mut via = vec![0.0; p];
        matvec_t_panel_with(KernelIsa::Scalar, &m, &r, 0..p, &mut via);
        for j in 0..p {
            assert_eq!(via[j].to_bits(), legacy[j].to_bits());
        }
        assert_eq!(
            dot_with(KernelIsa::Scalar, &r, &r).to_bits(),
            crate::linalg::dense::dot_scalar(&r, &r).to_bits()
        );
    }

    #[test]
    fn reduced_precision_dots_are_isa_invariant() {
        for n in [0usize, 1, 3, 8, 64, 101] {
            let (a64, b64) = vecs(n, 17 + n as u64);
            let a: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let want_mixed = dot_mixed_scalar(&a, &b);
            let want_f32 = dot_f32_scalar(&a, &b);
            for which in supported_isas() {
                assert_eq!(
                    dot_mixed_with(which, &a, &b).to_bits(),
                    want_mixed.to_bits(),
                    "{which:?} mixed n={n}"
                );
                assert_eq!(
                    dot_f32_with(which, &a, &b).to_bits(),
                    want_f32.to_bits(),
                    "{which:?} f32 n={n}"
                );
            }
            // reduced dots track the f64 dot at storage precision
            let exact = crate::linalg::dense::dot_scalar(&a64, &b64);
            let scale = (n as f64 + 1.0) * 100.0;
            assert!((want_mixed - exact).abs() <= 1e-4 * scale, "mixed n={n}");
            assert!((want_f32 - exact).abs() <= 1e-2 * scale, "f32 n={n}");
        }
    }

    #[test]
    fn shadow_is_aligned_padded_and_faithful() {
        for (n, p) in [(0usize, 0usize), (1, 1), (5, 3), (8, 4), (13, 9)] {
            let data: Vec<f64> = (0..n * p).map(|k| (k as f64) * 0.5 - 3.0).collect();
            let m = DenseMatrix::from_col_major(n, p, data);
            let s = ShadowF32::from_dense(&m);
            assert_eq!(s.nrows(), n);
            assert_eq!(s.ncols(), p);
            for j in 0..p {
                let col = s.col(j);
                assert_eq!(col.as_ptr() as usize % 32, 0, "col {j} not 32-byte aligned");
                for i in 0..n {
                    assert_eq!(col[i], m.col(j)[i] as f32);
                }
            }
            assert!(s.bytes() >= n * p * 4);
        }
    }

    #[test]
    fn shadow_scans_match_per_column_reduced_dots() {
        let (n, p, b) = (9usize, 13usize, 3usize);
        let data: Vec<f64> = (0..n * p).map(|k| ((k * 7 % 17) as f64) - 8.0).collect();
        let m = DenseMatrix::from_col_major(n, p, data);
        let s = ShadowF32::from_dense(&m);
        let (r64, _) = vecs(n, 3);
        let mut r32 = Vec::new();
        to_f32(&r64, &mut r32);
        for prec in [Precision::F32, Precision::Mixed] {
            let mut out = vec![0.0; p];
            shadow_matvec_t(&s, &r32, prec, 0.25, &mut out);
            for j in 0..p {
                let want = 0.25 * reduced_dot(prec, s.col(j), &r32);
                assert_eq!(out[j].to_bits(), want.to_bits(), "{prec:?} j={j}");
            }
            let (panel64, _) = vecs(n * b, 41);
            let mut panel32 = Vec::new();
            to_f32(&panel64, &mut panel32);
            let mut mm = vec![0.0; p * b];
            shadow_matmul_t(&s, &panel32, b, prec, &mut mm);
            for j in 0..p {
                for c in 0..b {
                    let want = reduced_dot(prec, s.col(j), &panel32[c * n..(c + 1) * n]);
                    assert_eq!(mm[j * b + c].to_bits(), want.to_bits(), "{prec:?} j={j} c={c}");
                }
            }
        }
    }
}
