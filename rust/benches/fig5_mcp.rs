//! Bench: regenerate paper Figure 5 (MCP objective + stationarity vs time).
//!
//! `cargo bench --bench fig5_mcp [-- --full]` — smoke scale by default.
//! Writes CSV/JSON series under `results/` (criterion is unavailable
//! offline; timing comes from the benchopt-style harness).

use skglm::bench::figures::{run_fig5, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    eprintln!("[fig5_mcp] scale = {scale:?}");
    let t0 = std::time::Instant::now();
    match run_fig5(scale) {
        Ok(paths) => {
            for p in &paths {
                println!("wrote {}", p.display());
            }
            println!("[fig5_mcp] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig5_mcp failed: {e:#}");
            std::process::exit(1);
        }
    }
}
