//! Bench target for the block-coordinate group engine: block CD vs scalar
//! CD on the ungrouped ℓ1 relaxation vs the proximal-gradient baseline,
//! same grid as `skglm exp groups` (smoke scale by default; pass `--full`
//! for the full group-size/density grid). Results also land in
//! `results/groups/BENCH_groups.json`.

use skglm::bench::figures::Scale;
use skglm::bench::group_bench::run_groups;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    match run_groups(scale) {
        Ok(paths) => {
            for p in paths {
                println!("wrote {}", p.display());
            }
        }
        Err(e) => {
            eprintln!("group bench failed: {e:#}");
            std::process::exit(2);
        }
    }
}
