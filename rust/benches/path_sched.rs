//! Bench: cold-fit-per-λ vs warm-started path scheduling (the coordinator
//! tentpole) on the Figure-1 dataset.
//!
//! `cargo bench --bench path_sched [-- --full]` — smoke scale by default;
//! `--full` runs the EXPERIMENTS.md configuration (n = 1000, p = 2000,
//! 30 path points). Prints the epoch/wall-time comparison and writes the
//! markdown table under `results/pathsched/`.

use skglm::bench::figures::Scale;
use skglm::bench::path_bench::run_pathsched;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    eprintln!("[path_sched] scale = {scale:?}");
    let t0 = std::time::Instant::now();
    match run_pathsched(scale) {
        Ok(paths) => {
            for p in &paths {
                println!("wrote {}", p.display());
                if p.extension().map(|e| e == "md").unwrap_or(false) {
                    println!("\n== {} ==", p.display());
                    println!("{}", std::fs::read_to_string(p).unwrap_or_default());
                }
            }
            println!("[path_sched] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("pathsched failed: {e:#}");
            std::process::exit(1);
        }
    }
}
