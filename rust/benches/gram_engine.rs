//! Bench target for the Gram-domain inner engine: residual vs Gram vs
//! auto dispatch on the same grid as `skglm exp gram` (smoke scale by
//! default; pass `--full` for the large grid). Results also land in
//! `results/gram/BENCH_gram.json`.

use skglm::bench::figures::Scale;
use skglm::bench::gram_bench::run_gram;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    match run_gram(scale) {
        Ok(paths) => {
            for p in paths {
                println!("wrote {}", p.display());
            }
        }
        Err(e) => {
            eprintln!("gram bench failed: {e:#}");
            std::process::exit(2);
        }
    }
}
