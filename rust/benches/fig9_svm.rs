//! Bench: regenerate paper Figure 9 (dual SVM suboptimality vs time).
//!
//! `cargo bench --bench fig9_svm [-- --full]` — smoke scale by default.
//! Writes CSV/JSON series under `results/` (criterion is unavailable
//! offline; timing comes from the benchopt-style harness).

use skglm::bench::figures::{run_fig9, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    eprintln!("[fig9_svm] scale = {scale:?}");
    let t0 = std::time::Instant::now();
    match run_fig9(scale) {
        Ok(paths) => {
            for p in &paths {
                println!("wrote {}", p.display());
            }
            println!("[fig9_svm] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig9_svm failed: {e:#}");
            std::process::exit(1);
        }
    }
}
