//! Micro-benchmarks of the solver hot paths (criterion replacement:
//! warmup + repeated timing with median-of-reps reporting).
//!
//! Covers: dense/sparse CD epochs, the full-gradient scoring pass
//! (native vs PJRT artifact when available), Anderson extrapolation,
//! prox throughput. These are the §Perf numbers in EXPERIMENTS.md.

use skglm::bench::kernel_bench::time_it;
use skglm::data::{correlated, paper_dataset_small, sparse, CorrelatedSpec, SparseSpec};
use skglm::datafit::{Datafit, Quadratic};
use skglm::linalg::Design;
use skglm::penalty::{Mcp, L1};
use skglm::solver::anderson::Anderson;
use skglm::solver::cd::cd_epoch;
use std::hint::black_box;

fn row(name: &str, secs: f64, work_items: f64) {
    println!(
        "{name:<42} {:>10.3} µs   {:>10.1} Mitem/s",
        secs * 1e6,
        work_items / secs / 1e6
    );
}

fn bench_cd_epoch_dense() {
    let ds = correlated(CorrelatedSpec { n: 1000, p: 2000, rho: 0.5, nnz: 100, snr: 8.0 }, 0);
    let mut f = Quadratic::new();
    f.init(&ds.design, &ds.y);
    let pen = L1::new(skglm::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 20.0);
    let ws: Vec<usize> = (0..ds.p()).collect();
    let mut beta = vec![0.0; ds.p()];
    let mut state = f.init_state(&ds.design, &ds.y, &beta);
    let secs = time_it(3, 9, || {
        black_box(cd_epoch(&ds.design, &ds.y, &f, &pen, &mut beta, &mut state, &ws));
    });
    // one epoch touches n*p entries (dense)
    row("cd_epoch dense 1000x2000 (full sweep)", secs, (ds.n() * ds.p()) as f64);
}

fn bench_cd_epoch_sparse() {
    let ds = paper_dataset_small("news20", 0).unwrap();
    let nnz = match &ds.design {
        Design::Sparse(s) => s.nnz(),
        _ => unreachable!(),
    };
    let mut f = Quadratic::new();
    f.init(&ds.design, &ds.y);
    let pen = L1::new(skglm::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / 20.0);
    let ws: Vec<usize> = (0..ds.p()).collect();
    let mut beta = vec![0.0; ds.p()];
    let mut state = f.init_state(&ds.design, &ds.y, &beta);
    let secs = time_it(3, 9, || {
        black_box(cd_epoch(&ds.design, &ds.y, &f, &pen, &mut beta, &mut state, &ws));
    });
    row(
        &format!("cd_epoch sparse news20-small ({nnz} nnz)"),
        secs,
        nnz as f64,
    );
}

fn bench_cd_epoch_mcp() {
    let ds = correlated(CorrelatedSpec { n: 1000, p: 2000, rho: 0.5, nnz: 100, snr: 8.0 }, 1);
    let mut design = ds.design.clone();
    design.normalize_cols((1000.0f64).sqrt());
    let mut f = Quadratic::new();
    f.init(&design, &ds.y);
    let pen = Mcp::new(
        skglm::estimators::linear::quadratic_lambda_max(&design, &ds.y) / 20.0,
        3.0,
    );
    let ws: Vec<usize> = (0..ds.p()).collect();
    let mut beta = vec![0.0; ds.p()];
    let mut state = f.init_state(&design, &ds.y, &beta);
    let secs = time_it(3, 9, || {
        black_box(cd_epoch(&design, &ds.y, &f, &pen, &mut beta, &mut state, &ws));
    });
    row("cd_epoch dense MCP 1000x2000", secs, (ds.n() * ds.p()) as f64);
}

fn bench_scoring_pass(n: usize, p: usize) {
    let ds = correlated(
        CorrelatedSpec { n, p, rho: 0.5, nnz: p / 20, snr: 8.0 },
        2,
    );
    let mut f = Quadratic::new();
    f.init(&ds.design, &ds.y);
    let beta = vec![0.0; p];
    let state = f.init_state(&ds.design, &ds.y, &beta);
    let mut grad = vec![0.0; p];
    let secs = time_it(3, 9, || {
        f.grad_full(&ds.design, &ds.y, &state, &beta, &mut grad);
        black_box(&grad);
    });
    row(&format!("scoring pass native {n}x{p}"), secs, (n * p) as f64);

    // PJRT path when the artifact exists
    if skglm::runtime::PjrtRuntime::available("xt_r", n, p) {
        if let Ok(rt) = skglm::runtime::PjrtRuntime::cpu() {
            if let Ok(mut engine) = skglm::runtime::PjrtGradEngine::for_design(&rt, &ds.design) {
                use skglm::solver::GradEngine;
                let secs = time_it(3, 9, || {
                    assert!(engine.grad_full(&ds.design, &ds.y, &state, &beta, &mut grad));
                    black_box(&grad);
                });
                row(&format!("scoring pass pjrt   {n}x{p}"), secs, (n * p) as f64);
            }
        }
    } else {
        println!("scoring pass pjrt   {n}x{p}: skipped (no artifact — run `make artifacts`)");
    }
}

fn bench_anderson() {
    for dim in [100usize, 2000] {
        let mut an = Anderson::new(5);
        let base: Vec<f64> = (0..dim).map(|i| (i as f64).sin()).collect();
        for k in 0..6 {
            let x: Vec<f64> = base.iter().map(|v| v * 0.9f64.powi(k)).collect();
            an.push(&x);
        }
        let secs = time_it(3, 15, || {
            black_box(an.extrapolate());
        });
        row(&format!("anderson extrapolate M=5 dim={dim}"), secs, dim as f64 * 25.0);
    }
}

fn bench_panel_xtr() {
    // the blocked 8-column panel Xᵀr vs the naive per-column dot, plus the
    // parallel variant at the full thread budget (fig1-scale dense design)
    let ds = correlated(CorrelatedSpec { n: 1000, p: 2000, rho: 0.5, nnz: 100, snr: 8.0 }, 4);
    let work = (ds.n() * ds.p()) as f64;
    let r: Vec<f64> = (0..ds.n()).map(|i| (i as f64).sin()).collect();
    let mut out = vec![0.0; ds.p()];

    let naive = match &ds.design {
        Design::Dense(m) => time_it(3, 9, || {
            m.matvec_t(&r, &mut out);
            black_box(&out);
        }),
        Design::Sparse(_) => unreachable!("correlated designs are dense"),
    };
    row("xtr naive per-column 1000x2000", naive, work);

    let blocked = time_it(3, 9, || {
        ds.design.matvec_t_threads(&r, &mut out, 1);
        black_box(&out);
    });
    row("xtr blocked panel    1000x2000", blocked, work);

    let budget = skglm::linalg::parallel::thread_budget();
    let parallel = time_it(3, 9, || {
        ds.design.matvec_t_threads(&r, &mut out, budget);
        black_box(&out);
    });
    row(
        &format!("xtr parallel x{budget}      1000x2000"),
        parallel,
        work,
    );
}

fn bench_sparse_col_dot() {
    // single-column sparse dot: the innermost CD primitive, and the unit
    // of work the nnz-balanced chunking distributes
    let ds = sparse(
        "bench",
        SparseSpec { n: 5000, p: 50_000, density: 1e-3, support_frac: 0.001, snr: 5.0, binary: false },
        5,
    );
    let m = match &ds.design {
        Design::Sparse(m) => m,
        Design::Dense(_) => unreachable!(),
    };
    let r: Vec<f64> = (0..ds.n()).map(|i| (i as f64).cos()).collect();
    let nnz = m.nnz();
    let secs = time_it(3, 9, || {
        let mut acc = 0.0;
        for j in 0..m.ncols() {
            acc += m.col_dot(j, &r);
        }
        black_box(acc);
    });
    row(&format!("sparse col_dot sweep ({nnz} nnz)"), secs, nnz as f64);
}

fn bench_sparse_matvec_t() {
    let ds = sparse(
        "bench",
        SparseSpec { n: 5000, p: 50_000, density: 1e-3, support_frac: 0.001, snr: 5.0, binary: false },
        3,
    );
    let nnz = ds.design.stored_entries();
    let r: Vec<f64> = (0..ds.n()).map(|i| (i as f64).cos()).collect();
    let mut out = vec![0.0; ds.p()];
    let secs = time_it(2, 7, || {
        ds.design.matvec_t(&r, &mut out);
        black_box(&out);
    });
    row(&format!("sparse matvec_t 5000x50000 ({nnz} nnz)"), secs, nnz as f64);
}

fn main() {
    println!("micro_kernels — median of reps, warmup excluded\n");
    bench_cd_epoch_dense();
    bench_cd_epoch_sparse();
    bench_cd_epoch_mcp();
    bench_scoring_pass(200, 400);
    bench_scoring_pass(1000, 2000);
    bench_panel_xtr();
    bench_anderson();
    bench_sparse_matvec_t();
    bench_sparse_col_dot();
}
