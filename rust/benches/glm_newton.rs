//! Bench target for the prox-Newton GLM subsystem: prox-Newton vs OWL-QN
//! on ℓ1-Poisson/probit, same grid as `skglm exp glms` (smoke scale by
//! default; pass `--full` for the full n/p grid). Results also land in
//! `results/glms/BENCH_glms.json`.

use skglm::bench::figures::Scale;
use skglm::bench::glm_bench::run_glms;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    match run_glms(scale) {
        Ok(paths) => {
            for p in paths {
                println!("wrote {}", p.display());
            }
        }
        Err(e) => {
            eprintln!("glm bench failed: {e:#}");
            std::process::exit(2);
        }
    }
}
