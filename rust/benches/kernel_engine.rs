//! Bench target for the kernel engine: serial vs blocked vs parallel
//! O(n·p) passes on the same grid as `skglm exp kernels` (smoke scale by
//! default; pass `--full` for the fig1-scale grid). Results also land in
//! `results/kernels/BENCH_kernels.json`.

use skglm::bench::figures::Scale;
use skglm::bench::kernel_bench::run_kernels;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    match run_kernels(scale) {
        Ok(paths) => {
            for p in paths {
                println!("wrote {}", p.display());
            }
        }
        Err(e) => {
            eprintln!("kernel bench failed: {e:#}");
            std::process::exit(2);
        }
    }
}
