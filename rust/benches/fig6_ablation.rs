//! Bench: regenerate paper Figure 6 (working-set x Anderson ablation).
//!
//! `cargo bench --bench fig6_ablation [-- --full]` — smoke scale by default.
//! Writes CSV/JSON series under `results/` (criterion is unavailable
//! offline; timing comes from the benchopt-style harness).

use skglm::bench::figures::{run_fig6, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    eprintln!("[fig6_ablation] scale = {scale:?}");
    let t0 = std::time::Instant::now();
    match run_fig6(scale) {
        Ok(paths) => {
            for p in &paths {
                println!("wrote {}", p.display());
            }
            println!("[fig6_ablation] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig6_ablation failed: {e:#}");
            std::process::exit(1);
        }
    }
}
