//! Bench: regenerate paper Figure 3 (elastic net gap vs time).
//!
//! `cargo bench --bench fig3_enet [-- --full]` — smoke scale by default.
//! Writes CSV/JSON series under `results/` (criterion is unavailable
//! offline; timing comes from the benchopt-style harness).

use skglm::bench::figures::{run_fig3, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    eprintln!("[fig3_enet] scale = {scale:?}");
    let t0 = std::time::Instant::now();
    match run_fig3(scale) {
        Ok(paths) => {
            for p in &paths {
                println!("wrote {}", p.display());
            }
            println!("[fig3_enet] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig3_enet failed: {e:#}");
            std::process::exit(1);
        }
    }
}
