//! Bench: regenerate paper Figure 2 (Lasso duality gap vs time).
//!
//! `cargo bench --bench fig2_lasso [-- --full]` — smoke scale by default;
//! `--full` runs the EXPERIMENTS.md configuration. Prints the
//! time-to-target summary per (dataset, λ) and writes CSV/JSON under
//! `results/fig2/`. (criterion is unavailable offline; the benchopt-style
//! harness in `skglm::bench` does the timing.)

use skglm::bench::figures::{run_fig2, Scale};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { Scale::Full } else { Scale::Smoke };
    eprintln!("[fig2_lasso] scale = {scale:?}");
    let t0 = std::time::Instant::now();
    match run_fig2(scale) {
        Ok(paths) => {
            for p in &paths {
                println!("wrote {}", p.display());
            }
            // print the summaries inline for the bench log
            for p in paths.iter().filter(|p| p.extension().map(|e| e == "md").unwrap_or(false)) {
                println!("\n== {} ==", p.display());
                println!("{}", std::fs::read_to_string(p).unwrap_or_default());
            }
            println!("[fig2_lasso] done in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig2 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
