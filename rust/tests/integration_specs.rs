//! Integration: every `coordinator::specs::*` constructor round-trips
//! through the real scheduler — one `Job::Fit` at λ_max/5 and one 3-λ
//! `Job::Path` each — without panicking on a worker, and returns finite
//! objectives with its declared metadata intact. This is the
//! constructor-level complement to the scenario conformance corpus
//! (`skglm conform`): the corpus certifies solver quality per
//! (datafit × penalty); this test certifies that *every* public spec
//! constructor is schedulable at all.

use skglm::coordinator::{specs, FitScheduler, FitSpec, JobEvent};
use skglm::data::{
    correlated, grouped_correlated, poisson_correlated, probit_correlated, CorrelatedSpec,
    Dataset, GroupedSpec,
};
use skglm::solver::SolverOpts;
use std::sync::Arc;

const RATIOS: [f64; 3] = [0.5, 0.25, 0.1];

fn quad_dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(correlated(CorrelatedSpec { n: 60, p: 90, rho: 0.5, nnz: 8, snr: 10.0 }, seed))
}

/// Multitask targets in the task-major layout (`y[t·n + i]`): each task
/// regresses on the same design with a sign-flipped planted signal.
fn multitask_dataset(n: usize, p: usize, n_tasks: usize, seed: u64) -> Arc<Dataset> {
    let base = correlated(CorrelatedSpec { n, p, rho: 0.5, nnz: 6, snr: 10.0 }, seed);
    let mut y = vec![0.0; n * n_tasks];
    let mut xb = vec![0.0; n];
    for t in 0..n_tasks {
        let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
        let w: Vec<f64> = base.beta_true.iter().map(|&b| sign * b).collect();
        base.design.matvec(&w, &mut xb);
        for i in 0..n {
            y[t * n + i] = xb[i];
        }
    }
    Arc::new(Dataset {
        name: format!("specs_mtl_{seed}"),
        design: base.design,
        y,
        beta_true: Vec::new(),
    })
}

/// Submit one single fit (at λ_max/5) + one 3-λ path for the spec and
/// assert both complete with finite objectives and no worker failure.
fn roundtrip(name: &str, ds: &Arc<Dataset>, make: &dyn Fn(f64) -> Box<dyn FitSpec>) {
    let proto = make(1.0);
    let lam_max = proto.lambda_max(&ds.design, &ds.y);
    assert!(
        lam_max.is_finite() && lam_max > 0.0,
        "{name}: lambda_max = {lam_max} is not a usable anchor"
    );

    let opts = SolverOpts::default().with_tol(1e-6);
    let sched = FitScheduler::start(2);
    let fit_job = sched.submit_fit(Arc::clone(ds), make(lam_max / 5.0), opts.clone());
    let path_job = sched.submit_path(Arc::clone(ds), make(1.0), RATIOS.to_vec(), opts);

    // terminal events: FitDone + PathDone (a Failed in either slot is a
    // hard failure, reported with its original panic message)
    let mut fit_done = false;
    let mut path_points = 0usize;
    let mut path_done = false;
    while !(fit_done && path_done) {
        match sched.events.recv().expect("scheduler died") {
            JobEvent::FitDone(o) => {
                assert_eq!(o.job_id, fit_job, "{name}: unexpected fit job id");
                assert!(
                    o.result.objective.is_finite(),
                    "{name}: single fit returned objective {}",
                    o.result.objective
                );
                fit_done = true;
            }
            JobEvent::PathPoint(p) => {
                assert_eq!(p.job_id, path_job);
                assert!(
                    p.point.objective.is_finite(),
                    "{name}: path point {} returned objective {}",
                    p.index,
                    p.point.objective
                );
                path_points += 1;
            }
            JobEvent::PathDone(s) => {
                assert_eq!(s.job_id, path_job);
                path_done = true;
            }
            JobEvent::Failed { job_id, message } => {
                panic!("{name}: job {job_id} panicked on its worker: {message}")
            }
            JobEvent::Cancelled { job_id, .. } => {
                panic!("{name}: job {job_id} unexpectedly cancelled")
            }
            JobEvent::SchedulerDown => panic!("{name}: scheduler died"),
        }
    }
    sched.shutdown();
    assert_eq!(path_points, RATIOS.len(), "{name}: path dropped points");
}

#[test]
fn every_scalar_quadratic_spec_is_schedulable() {
    let ds = quad_dataset(3);
    let p = ds.design.ncols();
    let cases: Vec<(&str, Box<dyn Fn(f64) -> Box<dyn FitSpec>>)> = vec![
        ("lasso", Box::new(specs::lasso)),
        (
            "weighted_lasso",
            Box::new(move |l| {
                specs::weighted_lasso(l, (0..p).map(|j| 0.5 + 0.5 * ((j % 3) as f64)).collect())
            }),
        ),
        ("elastic_net", Box::new(|l| specs::elastic_net(l, 0.7))),
        ("mcp", Box::new(|l| specs::mcp(l, 3.0))),
        ("scad", Box::new(|l| specs::scad(l, 3.7))),
        ("lq", Box::new(|l| specs::lq(l, 0.5))),
    ];
    for (name, make) in &cases {
        roundtrip(name, &ds, make.as_ref());
    }
}

#[test]
fn every_glm_spec_is_schedulable() {
    let spec = CorrelatedSpec { n: 60, p: 90, rho: 0.5, nnz: 8, snr: 10.0 };
    let logit = Arc::new(probit_correlated(spec, 5));
    roundtrip("logistic_l1", &logit, &specs::logistic_l1);

    let pois = Arc::new(poisson_correlated(CorrelatedSpec { snr: 0.0, ..spec }, 6));
    roundtrip("poisson_l1", &pois, &specs::poisson_l1);

    let prob = Arc::new(probit_correlated(spec, 7));
    roundtrip("probit_l1", &prob, &specs::probit_l1);
}

#[test]
fn every_group_spec_is_schedulable() {
    let (ds, part) = grouped_correlated(
        GroupedSpec { n: 80, p: 60, group_size: 5, active_groups: 3, rho: 0.5, snr: 10.0 },
        9,
    );
    let ds = Arc::new(ds);
    let cases: Vec<(&str, Box<dyn Fn(f64) -> Box<dyn FitSpec>>)> = vec![
        ("group_lasso", {
            let part = Arc::clone(&part);
            Box::new(move |l| specs::group_lasso(l, Arc::clone(&part)))
        }),
        ("weighted_group_lasso", {
            let part = Arc::clone(&part);
            Box::new(move |l| specs::weighted_group_lasso(l, Arc::clone(&part)))
        }),
        ("group_mcp", {
            let part = Arc::clone(&part);
            Box::new(move |l| specs::group_mcp(l, 3.0, Arc::clone(&part)))
        }),
        ("group_scad", {
            let part = Arc::clone(&part);
            Box::new(move |l| specs::group_scad(l, 3.7, Arc::clone(&part)))
        }),
    ];
    for (name, make) in &cases {
        roundtrip(name, &ds, make.as_ref());
    }
}

#[test]
fn every_multitask_spec_is_schedulable() {
    let (n, p, n_tasks) = (50, 30, 3);
    let ds = multitask_dataset(n, p, n_tasks, 13);
    roundtrip("multitask_l21", &ds, &|l| specs::multitask_l21(l, p, n_tasks));
    roundtrip("multitask_mcp", &ds, &|l| specs::multitask_mcp(l, 3.0, p, n_tasks));
}
