//! Integration: `skglm analyze` run against this very repository.
//!
//! The self-scan is the point of the whole subsystem: the analyzer ships
//! inside the binary it audits, so the gate below ("the checked-in tree
//! has zero findings") is what CI enforces. A second test proves the
//! gate has teeth — a deliberately violating tree must fail.

use skglm::analysis::{analyze_repo, run};
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // rust/ -> repo root
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

#[test]
fn self_scan_is_clean() {
    let report = analyze_repo(&repo_root()).expect("self-scan runs");
    assert!(report.files_scanned > 20, "expected the full tree, got {}", report.files_scanned);
    if !report.outcome.findings.is_empty() {
        for f in &report.outcome.findings {
            eprintln!("[self-scan] {}:{} [{}] {}", f.file, f.line, f.rule_id, f.excerpt);
            eprintln!("[self-scan]     {}", f.justification);
        }
        panic!(
            "{} static-analysis finding(s) in the checked-in tree; fix them or \
             justify with `// lint: allow(rule, reason)`",
            report.outcome.findings.len()
        );
    }
}

#[test]
fn self_scan_inventories_unsafe_and_suppressions() {
    let report = analyze_repo(&repo_root()).expect("self-scan runs");
    // linalg/parallel.rs's pool is the only unsafe in the tree; every
    // site must carry a SAFETY comment
    assert!(!report.outcome.unsafe_inventory.is_empty(), "unsafe inventory must not be empty");
    for site in &report.outcome.unsafe_inventory {
        assert!(
            site.file.contains("linalg/parallel.rs"),
            "unexpected unsafe outside the kernel pool: {}:{}",
            site.file,
            site.line
        );
        assert!(site.has_safety, "unsafe without SAFETY at {}:{}", site.file, site.line);
    }
    // suppressions exist (the documented allows) and every one is used —
    // a dead allow means the justification outlived the violation
    assert!(!report.outcome.suppressions.is_empty());
    for s in &report.outcome.suppressions {
        assert!(s.used, "unused suppression at {}:{} for {}", s.file, s.line, s.rule_id);
        assert!(!s.reason.is_empty(), "empty reason at {}:{}", s.file, s.line);
    }
}

#[test]
fn violating_tree_fails_the_gate() {
    let root =
        std::env::temp_dir().join(format!("skglm_analyze_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let src = root.join("rust").join("src").join("coordinator");
    std::fs::create_dir_all(&src).expect("mkdir fixture");
    std::fs::write(
        src.join("wire.rs"),
        "fn f(v: Vec<u8>) -> u8 { v[0] }\n\
         fn g(o: Option<u8>) -> u8 { o.unwrap() }\n",
    )
    .expect("write fixture");

    let report = analyze_repo(&root).expect("fixture scan runs");
    assert_eq!(report.outcome.findings.len(), 2, "{:?}", report.outcome.findings);
    assert!(report.outcome.findings.iter().all(|f| f.rule_id == "panic-audit"));
    assert!(report.outcome.findings.iter().all(|f| f.severity == "error"));

    // the CLI entry point fails loudly on the same tree (quiet mode, and
    // results redirected so the fixture run cannot clobber real reports)
    let out = root.join("results");
    std::env::set_var("SKGLM_RESULTS", &out);
    let err = run(&root, true).expect_err("violating tree must fail the gate");
    assert!(err.to_string().contains("finding"), "{err}");
    assert!(out.join("analysis").join("BENCH_analysis.json").exists());
    std::env::remove_var("SKGLM_RESULTS");
    let _ = std::fs::remove_dir_all(&root);
}
