//! Cross-solver integration: every algorithm that optimises the same
//! convex objective must land on the same optimum; non-convex solvers
//! must reach genuine critical points; Proposition-10-style support
//! identification must hold on well-conditioned designs.

use skglm::data::{correlated, paper_dataset_small, CorrelatedSpec};
use skglm::datafit::{Datafit, Logistic, Quadratic};
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::estimators::{ElasticNet, Lasso, LinearSvc, McpRegressor};
use skglm::linalg::Design;
use skglm::metrics::{lasso_gap, stationarity, support_recovery};
use skglm::penalty::{Mcp, Penalty, L1};
use skglm::solver::baselines::{
    admm::solve_admm, celer::solve_celer, fireworks::solve_fireworks, irls::solve_irls_mcp,
    pgd::solve_pgd, strong_rules::solve_strong_rules_enet,
};
use skglm::solver::{solve, SolverOpts};

fn residual(design: &Design, y: &[f64], beta: &[f64]) -> Vec<f64> {
    let mut xb = vec![0.0; design.nrows()];
    design.matvec(beta, &mut xb);
    y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect()
}

/// Six Lasso solvers, one optimum.
#[test]
fn all_lasso_solvers_agree_on_the_optimum() {
    let ds = correlated(CorrelatedSpec { n: 120, p: 200, rho: 0.5, nnz: 10, snr: 10.0 }, 77);
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / 25.0;
    let pen = L1::new(lam);
    let tol = 1e-11;

    let mut objs: Vec<(&str, f64)> = Vec::new();

    let mut f = Quadratic::new();
    let skglm_fit =
        solve(&ds.design, &ds.y, &mut f, &pen, &SolverOpts::default().with_tol(tol), None, None);
    objs.push(("skglm", skglm_fit.objective));

    let mut f = Quadratic::new();
    let mut opts = SolverOpts::default().with_tol(tol).without_ws().without_acceleration();
    opts.max_epochs = 100_000;
    objs.push(("full_cd", solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None).objective));

    objs.push(("celer_like", solve_celer(&ds.design, &ds.y, lam, &SolverOpts::default().with_tol(tol)).objective));

    let mut f = Quadratic::new();
    objs.push((
        "fireworks",
        solve_fireworks(&ds.design, &ds.y, &mut f, &pen, &SolverOpts::default().with_tol(tol)).objective,
    ));

    let mut f = Quadratic::new();
    objs.push(("fista", solve_pgd(&ds.design, &ds.y, &mut f, &pen, 200_000, tol, true).objective));

    objs.push(("admm", solve_admm(&ds.design, &ds.y, lam, 1.0, 1.0, 20_000, 1e-12).objective));

    let reference = objs[0].1;
    for (name, obj) in &objs {
        assert!(
            (obj - reference).abs() < 1e-7 * reference.abs().max(1.0),
            "{name} objective {obj} != skglm {reference}"
        );
    }
    // and the skglm point satisfies the duality certificate
    let r = residual(&ds.design, &ds.y, &skglm_fit.beta);
    assert!(lasso_gap(&ds.design, &ds.y, &skglm_fit.beta, &r, lam) < 1e-9);
}

#[test]
fn enet_solvers_agree() {
    let ds = correlated(CorrelatedSpec { n: 90, p: 140, rho: 0.5, nnz: 8, snr: 10.0 }, 78);
    let rho = 0.5;
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / rho / 30.0;
    let a = ElasticNet::new(lam, rho).with_tol(1e-11).fit(&ds.design, &ds.y);
    let b = solve_strong_rules_enet(&ds.design, &ds.y, lam, rho, 25, 20_000, 1e-11);
    let c = solve_admm(&ds.design, &ds.y, lam, rho, 1.0, 20_000, 1e-12);
    assert!((a.objective - b.objective).abs() < 1e-7);
    assert!((a.objective - c.objective).abs() < 1e-7);
}

/// Proposition 10 in practice: after convergence on a well-conditioned
/// problem, the generalized support matches the (identifiable) truth and
/// the KKT residual certifies a critical point.
#[test]
fn mcp_support_identification_and_criticality() {
    let ds = correlated(CorrelatedSpec { n: 300, p: 600, rho: 0.3, nnz: 15, snr: 20.0 }, 79);
    let lam_ref = quadratic_lambda_max(&ds.design, &ds.y);
    let (fit, scales) = McpRegressor::new(lam_ref / 15.0, 3.0)
        .with_tol(1e-10)
        .fit(&ds.design, &ds.y);
    assert!(fit.converged, "kkt {}", fit.kkt);
    // support identification: exact recovery at this SNR
    let beta_orig: Vec<f64> =
        fit.beta.iter().zip(scales.iter()).map(|(b, s)| b * s).collect();
    let rec = support_recovery(&beta_orig, &ds.beta_true, 1e-8);
    assert!(rec.exact, "tp={} fp={} fn={}", rec.true_positives, rec.false_positives, rec.false_negatives);
    // near-unbiasedness: MCP coefficient magnitudes ≈ truth (within noise)
    for (j, &bt) in ds.beta_true.iter().enumerate() {
        if bt != 0.0 {
            assert!(
                (beta_orig[j] - bt).abs() < 0.2,
                "coef {j}: {} vs {}",
                beta_orig[j],
                bt
            );
        }
    }
}

#[test]
fn irls_and_skglm_mcp_reach_critical_points_of_same_objective() {
    let ds = correlated(CorrelatedSpec { n: 150, p: 250, rho: 0.4, nnz: 12, snr: 10.0 }, 80);
    let mut design = ds.design.clone();
    design.normalize_cols((150.0f64).sqrt());
    let lam = quadratic_lambda_max(&design, &ds.y) / 12.0;
    let gamma = 3.0;
    let pen = Mcp::new(lam, gamma);

    let mut f = Quadratic::new();
    let sk = solve(&design, &ds.y, &mut f, &pen, &SolverOpts::default().with_tol(1e-10), None, None);
    let ir = solve_irls_mcp(&design, &ds.y, lam, gamma, 30, &SolverOpts::default().with_tol(1e-10));

    let mut fq = Quadratic::new();
    fq.init(&design, &ds.y);
    for (name, beta) in [("skglm", &sk.beta), ("irls", &ir.beta)] {
        let state = fq.init_state(&design, &ds.y, beta);
        let s = stationarity(&design, &ds.y, &fq, &pen, beta, &state);
        assert!(s < 1e-6, "{name} stationarity {s}");
    }
}

#[test]
fn logistic_lasso_full_and_ws_agree_on_sparse_data() {
    let ds = paper_dataset_small("real-sim", 81).unwrap();
    let lam =
        skglm::estimators::SparseLogisticRegression::lambda_max(&ds.design, &ds.y) / 5.0;
    let pen = L1::new(lam);
    let mut f1 = Logistic::new();
    let a = solve(&ds.design, &ds.y, &mut f1, &pen, &SolverOpts::default().with_tol(1e-9), None, None);
    let mut f2 = Logistic::new();
    let mut opts = SolverOpts::default().with_tol(1e-9).without_ws();
    opts.max_epochs = 100_000;
    let b = solve(&ds.design, &ds.y, &mut f2, &pen, &opts, None, None);
    assert!(a.converged && b.converged);
    assert!((a.objective - b.objective).abs() < 1e-8);
}

/// Dual SVM: weak duality sanity — primal squared-hinge objective at the
/// recovered coefficients upper-bounds the negated dual optimum trend, and
/// the dual point is box-feasible with complementary slackness structure.
#[test]
fn svm_dual_structure() {
    let ds = correlated(CorrelatedSpec { n: 150, p: 12, rho: 0.3, nnz: 6, snr: 10.0 }, 82);
    let y: Vec<f64> = ds.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
    let x = match &ds.design {
        Design::Dense(m) => m.clone(),
        _ => unreachable!(),
    };
    let c = 1.0;
    let fit = LinearSvc::new(c).with_tol(1e-9).fit_dense(&x, &y);
    assert!(fit.alpha.converged);
    // complementary slackness: margin violations ⇒ α at C; safe points ⇒ α at 0
    let scores = LinearSvc::decision_function(&x, &fit.primal_coef);
    for i in 0..y.len() {
        let margin = y[i] * scores[i];
        let a = fit.alpha.beta[i];
        if margin > 1.0 + 1e-6 {
            assert!(a < 1e-7, "sample {i}: margin {margin} but alpha {a}");
        }
        if margin < 1.0 - 1e-6 {
            assert!((a - c).abs() < 1e-7, "sample {i}: margin {margin} but alpha {a}");
        }
    }
}

/// Warm-started path vs cold solves: identical optima at every λ.
#[test]
fn path_warm_starts_match_cold_solves() {
    let ds = correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.5, nnz: 8, snr: 10.0 }, 83);
    let ratios = skglm::estimators::path::geometric_grid(0.05, 6);
    let opts = SolverOpts::default().with_tol(1e-11);
    let path = skglm::estimators::path::lasso_path(&ds.design, &ds.y, None, &ratios, &opts);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    for pt in &path.points {
        let cold = Lasso::new(lam_max * pt.lambda_ratio).with_tol(1e-11).fit(&ds.design, &ds.y);
        assert!(
            (pt.objective - cold.objective).abs() < 1e-9,
            "λratio {}: warm {} vs cold {}",
            pt.lambda_ratio,
            pt.objective,
            cold.objective
        );
    }
}

/// The generalized support concept (Definition 4) unifies: for the box
/// penalty, gsupp = free variables, and the solver's working set finds it.
#[test]
fn gsupp_counts_free_dual_variables() {
    let ds = correlated(CorrelatedSpec { n: 60, p: 8, rho: 0.2, nnz: 4, snr: 5.0 }, 84);
    let y: Vec<f64> = ds.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
    let x = match &ds.design {
        Design::Dense(m) => m.clone(),
        _ => unreachable!(),
    };
    let fit = LinearSvc::new(0.5).with_tol(1e-9).fit_dense(&x, &y);
    let pen = skglm::penalty::BoxIndicator::new(0.5);
    let free = fit.alpha.beta.iter().filter(|&&a| pen.in_gsupp(a)).count();
    let bound = fit.alpha.beta.iter().filter(|&&a| !pen.in_gsupp(a)).count();
    assert_eq!(free + bound, 60);
    assert!(free > 0, "some margin points expected");
}
