//! ISSUE 5 integration: the Gram-domain inner engine through the full
//! stack — scheduler warm paths match the residual engine λ-by-λ, Gram
//! blocks persist across λ points and across jobs via the per-design
//! cache, and the auto dispatcher never loses to both fixed engines.

use skglm::coordinator::{specs, FitScheduler, JobEvent};
use skglm::data::{correlated, CorrelatedSpec, Dataset};
use skglm::datafit::Quadratic;
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::estimators::path::geometric_grid;
use skglm::penalty::L1;
use skglm::solver::{solve, ContinuationState, InnerEngine, SolverOpts};
use std::sync::Arc;

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(correlated(CorrelatedSpec { n: 120, p: 90, rho: 0.4, nnz: 7, snr: 10.0 }, seed))
}

/// Collect one path job's points, indexed by grid position.
fn run_path_job(
    sched: &FitScheduler,
    ds: &Arc<Dataset>,
    ratios: &[f64],
    inner: InnerEngine,
) -> Vec<Vec<f64>> {
    let job = sched.submit_path(
        Arc::clone(ds),
        specs::lasso(1.0),
        ratios.to_vec(),
        SolverOpts::default().with_tol(1e-14).with_inner(inner),
    );
    let mut points: Vec<Option<Vec<f64>>> = vec![None; ratios.len()];
    loop {
        match sched.events.recv().expect("scheduler died") {
            JobEvent::PathPoint(p) if p.job_id == job => {
                points[p.index] = Some(p.point.beta);
            }
            JobEvent::PathDone(s) if s.job_id == job => break,
            JobEvent::Failed { job_id, message } => {
                panic!("job {job_id} failed: {message}")
            }
            _ => {}
        }
    }
    points.into_iter().map(|p| p.expect("missing path point")).collect()
}

/// Acceptance: a warm path solve under `--inner gram` matches
/// `--inner residual` λ-by-λ through the scheduler, at 1e-12.
#[test]
fn scheduler_warm_path_gram_matches_residual_lambda_by_lambda() {
    let ds = dataset(3);
    // min ratio 0.05 keeps the restricted designs well-conditioned, so
    // the 1e-12 bar measures engine agreement rather than conditioning
    let ratios = geometric_grid(5e-2, 6);
    let sched = FitScheduler::start(1);
    let residual = run_path_job(&sched, &ds, &ratios, InnerEngine::Residual);
    let gram = run_path_job(&sched, &ds, &ratios, InnerEngine::Gram);
    sched.shutdown();
    for (idx, (br, bg)) in residual.iter().zip(gram.iter()).enumerate() {
        for (j, (a, b)) in br.iter().zip(bg.iter()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
                "path point {idx}, beta[{j}]: residual {a} vs gram {b}"
            );
        }
    }
}

/// Gram blocks live in the per-design cache entry: the first job pays the
/// assembly, later jobs on the same dataset reuse it.
#[test]
fn gram_blocks_are_shared_across_jobs_through_the_design_cache() {
    let ds = dataset(5);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let sched = FitScheduler::start(1);
    let opts = SolverOpts::default().with_tol(1e-10).with_inner(InnerEngine::Gram);
    sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 5.0), opts.clone());
    let _ = sched.collect_events(1);
    let entry = sched.cache().design_entry(&ds, false);
    let after_first = entry.gram.assembly_flops();
    assert!(after_first > 0, "first job must populate the shared Gram store");
    assert!(entry.gram.n_slots() > 0);

    // a second, nearby fit mostly re-uses the first job's blocks: its
    // incremental assembly is strictly less than a cold rebuild of its ws
    sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 6.0), opts.clone());
    let _ = sched.collect_events(1);
    let delta_warm = entry.gram.assembly_flops() - after_first;

    let ds_cold = dataset(5); // same content, fresh Arc ⇒ fresh store
    sched.submit_fit(Arc::clone(&ds_cold), specs::lasso(lam_max / 6.0), opts);
    let _ = sched.collect_events(1);
    let cold = sched.cache().design_entry(&ds_cold, false).gram.assembly_flops();
    assert!(
        delta_warm < cold,
        "shared store must amortize assembly: warm delta {delta_warm} vs cold {cold}"
    );
    sched.shutdown();
}

/// A warm continuation outside the scheduler also keeps one store across
/// λ points (solve_continued installs it lazily).
#[test]
fn continuation_state_carries_the_gram_store_across_lambdas() {
    let ds = dataset(7);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let opts = SolverOpts::default().with_tol(1e-10).with_inner(InnerEngine::Gram);
    let mut state = ContinuationState::default();
    let mut f = Quadratic::new();
    let a = skglm::solver::solve_continued(
        &ds.design, &ds.y, &mut f, &L1::new(lam_max / 4.0), &opts, None, &mut state, None, None,
    );
    assert!(a.converged);
    let store = state.gram.clone().expect("solve_continued must install a store");
    let flops_first = store.assembly_flops();
    assert!(flops_first > 0);
    let mut f2 = Quadratic::new();
    let b = skglm::solver::solve_continued(
        &ds.design, &ds.y, &mut f2, &L1::new(lam_max / 5.0), &opts, None, &mut state, None, None,
    );
    assert!(b.converged);
    assert!(Arc::ptr_eq(&store, state.gram.as_ref().unwrap()), "store must persist");
    let delta = store.assembly_flops() - flops_first;
    assert!(
        (delta as f64) < flops_first as f64,
        "second λ must reuse blocks: delta {delta} vs first {flops_first}"
    );
}

/// Acceptance: the auto dispatcher never picks a path worse than BOTH
/// fixed choices (by the recorded flop counters).
#[test]
fn auto_dispatch_is_never_worse_than_both_fixed_engines() {
    for (n, p, div) in [(400usize, 80usize, 8.0f64), (80, 300, 5.0), (250, 250, 12.0)] {
        let ds = correlated(
            CorrelatedSpec { n, p, rho: 0.5, nnz: (p / 15).max(2), snr: 8.0 },
            13,
        );
        let lam = quadratic_lambda_max(&ds.design, &ds.y) / div;
        let run = |inner: InnerEngine| {
            let mut f = Quadratic::new();
            let r = solve(
                &ds.design,
                &ds.y,
                &mut f,
                &L1::new(lam),
                &SolverOpts::default().with_tol(1e-10).with_inner(inner),
                None,
                None,
            );
            assert!(r.converged, "{n}x{p}: kkt {}", r.kkt);
            r.profile.total_flops()
        };
        let residual = run(InnerEngine::Residual);
        let gram = run(InnerEngine::Gram);
        let auto = run(InnerEngine::Auto);
        assert!(
            auto <= residual.max(gram) * 1.05,
            "{n}x{p} λ/{div}: auto {auto} worse than both residual {residual} and gram {gram}"
        );
    }
}

/// The screened fast path under the Gram engine stays exact: screened
/// solve == plain residual solve on the same λ.
#[test]
fn screened_gram_path_matches_plain_residual_solve() {
    let ds = dataset(9);
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
    let (fit, n_screened) = skglm::solver::screening::solve_lasso_screened(
        &ds.design,
        &ds.y,
        lam,
        &SolverOpts::default().with_tol(1e-12).with_inner(InnerEngine::Gram),
    );
    let mut f = Quadratic::new();
    let plain = solve(
        &ds.design,
        &ds.y,
        &mut f,
        &L1::new(lam),
        &SolverOpts::default().with_tol(1e-12),
        None,
        None,
    );
    assert!(
        (fit.objective - plain.objective).abs() < 1e-11,
        "screened-gram {} vs plain {}",
        fit.objective,
        plain.objective
    );
    assert!(n_screened > 0, "screening must still certify features");
    assert!(fit.profile.gram_epochs > 0, "the Gram engine must actually have run");
}
