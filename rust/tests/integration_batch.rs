//! Integration: scheduler-level many-fit fusion (ISSUE 9) — sibling
//! `Job::Fit`s on one design coalesce into one multi-RHS batched job,
//! sibling `Job::Path`s with one λ grid fuse into a λ-lockstep panel
//! sweep, and the fused jobs preserve the per-job contract: one event
//! stream per job id, single-member cancellation, deadline partials.
//!
//! Every test parks a long path job on the lone worker first so the
//! siblings are provably co-queued when the lead is dequeued — fusion is
//! then deterministic, not a race.

use skglm::coordinator::{specs, FitScheduler, Job, JobEvent, JobPolicy};
use skglm::data::{correlated, CorrelatedSpec, Dataset};
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::estimators::path::geometric_grid;
use skglm::solver::SolverOpts;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.5, nnz: 8, snr: 10.0 }, seed))
}

/// A path sweep heavy enough to occupy the lone worker for many
/// milliseconds while the microsecond-scale sibling submissions land.
fn submit_blocker(sched: &FitScheduler) -> (u64, usize) {
    let ds = Arc::new(correlated(
        CorrelatedSpec { n: 300, p: 500, rho: 0.5, nnz: 25, snr: 10.0 },
        99,
    ));
    let ratios = geometric_grid(1e-3, 16);
    let n_events = ratios.len() + 1;
    let id = sched.submit_path(ds, specs::lasso(1.0), ratios, SolverOpts::default().with_tol(1e-10));
    (id, n_events)
}

fn fit_done_by_job(events: &[JobEvent]) -> HashMap<u64, &skglm::coordinator::FitOutcome> {
    let mut map = HashMap::new();
    for e in events {
        if let JobEvent::FitDone(f) = e {
            map.insert(f.job_id, f);
        }
    }
    map
}

#[test]
fn sibling_fits_fuse_into_one_batched_job_and_match_scalar_runs() {
    let ds = dataset(41);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let lams = [lam_max / 4.0, lam_max / 10.0, lam_max / 25.0];
    let opts = SolverOpts::default().with_tol(1e-10);

    let sched = FitScheduler::start(1);
    let (blocker, blocker_events) = submit_blocker(&sched);
    let ids: Vec<u64> = lams
        .iter()
        .map(|&l| sched.submit_fit(Arc::clone(&ds), specs::lasso(l), opts.clone()))
        .collect();
    let events = sched.collect_events(blocker_events + lams.len());
    let stats = sched.fusion_stats();
    sched.shutdown();

    // one terminal event per job id, streamed on the shared channel
    let fits = fit_done_by_job(&events);
    assert_eq!(fits.len(), lams.len());
    for e in events.iter().filter(|e| e.job_id() != blocker) {
        assert!(ids.contains(&e.job_id()), "stray event for job {}", e.job_id());
    }
    // the three siblings ran as ONE batched job
    assert_eq!(stats.batched_jobs, 1, "siblings did not fuse: {stats:?}");
    assert_eq!(stats.batched_fits, 3);
    assert!((stats.fits_per_batch() - 3.0).abs() < 1e-12);
    assert!(
        stats.panel_flop_ratio() > 0.0 && stats.panel_flop_ratio() < 1.0,
        "panel share out of range: {}",
        stats.panel_flop_ratio()
    );

    // scalar reference: the same fits one at a time (nothing co-queued,
    // so nothing can fuse) — same optima, job by job
    let sched = FitScheduler::start(1);
    for (k, &l) in lams.iter().enumerate() {
        let id = sched.submit_fit(Arc::clone(&ds), specs::lasso(l), opts.clone());
        let events = sched.collect_events(1);
        match &events[0] {
            JobEvent::FitDone(f) => {
                assert_eq!(f.job_id, id);
                let fused = fits[&ids[k]];
                assert!(
                    (fused.result.objective - f.result.objective).abs()
                        <= 1e-8 * (1.0 + f.result.objective.abs()),
                    "member {k}: fused objective {} vs scalar {}",
                    fused.result.objective,
                    f.result.objective
                );
                assert!(fused.result.converged && f.result.converged);
            }
            other => panic!("expected FitDone, got event for job {}", other.job_id()),
        }
    }
    let stats = sched.fusion_stats();
    sched.shutdown();
    assert_eq!(stats.batched_jobs, 0, "sequential submissions must not fuse");
}

#[test]
fn cancelling_one_sibling_leaves_the_rest_of_the_batch_intact() {
    let ds = dataset(42);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let opts = SolverOpts::default().with_tol(1e-10);

    let sched = FitScheduler::start(1);
    let (blocker, blocker_events) = submit_blocker(&sched);
    let keep_a = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 5.0), opts.clone());
    let victim = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 10.0), opts.clone());
    let keep_b = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 20.0), opts.clone());
    assert!(sched.cancel(victim), "victim should still be live");
    let events = sched.collect_events(blocker_events + 3);
    sched.shutdown();

    let mut cancelled = Vec::new();
    let mut completed = Vec::new();
    for e in &events {
        match e {
            JobEvent::Cancelled { job_id, points_emitted } => {
                cancelled.push(*job_id);
                assert_eq!(*points_emitted, 0, "a cancelled fit emits no points");
            }
            JobEvent::FitDone(f) => completed.push(f.job_id),
            _ => assert_eq!(e.job_id(), blocker, "unexpected event {}", e.job_id()),
        }
    }
    assert_eq!(cancelled, vec![victim]);
    completed.sort_unstable();
    let mut expect = vec![keep_a, keep_b];
    expect.sort_unstable();
    assert_eq!(completed, expect, "surviving siblings must both complete");
}

#[test]
fn expired_deadline_retires_one_member_with_a_partial_result() {
    let ds = dataset(43);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let opts = SolverOpts::default().with_tol(1e-10);

    let sched = FitScheduler::start(1);
    let (_blocker, blocker_events) = submit_blocker(&sched);
    let healthy = sched.submit_fit(Arc::clone(&ds), specs::lasso(lam_max / 10.0), opts.clone());
    let (doomed, _ctl) = sched.submit_with(
        Job::Fit {
            dataset: Arc::clone(&ds),
            spec: specs::lasso(lam_max / 10.0),
            opts: opts.clone(),
        },
        JobPolicy::default().with_deadline(Instant::now()),
    );
    let events = sched.collect_events(blocker_events + 2);
    sched.shutdown();

    let fits = fit_done_by_job(&events);
    assert_eq!(fits.len(), 2);
    assert!(!fits[&healthy].timed_out, "healthy member must run to convergence");
    assert!(fits[&healthy].result.converged);
    assert!(fits[&doomed].timed_out, "expired deadline must report a partial");
    // the partial still carries a usable (if unconverged) iterate
    assert_eq!(fits[&doomed].result.beta.len(), ds.design.ncols());
}

#[test]
fn sibling_paths_fuse_and_stream_identical_per_member_sweeps() {
    let ds = dataset(44);
    let ratios = geometric_grid(1e-2, 5);
    let opts = SolverOpts::default().with_tol(1e-9);

    let sched = FitScheduler::start(1);
    let (blocker, blocker_events) = submit_blocker(&sched);
    let a = sched.submit_path(Arc::clone(&ds), specs::lasso(1.0), ratios.clone(), opts.clone());
    let b = sched.submit_path(Arc::clone(&ds), specs::lasso(1.0), ratios.clone(), opts);
    let events = sched.collect_events(blocker_events + 2 * (ratios.len() + 1));
    let stats = sched.fusion_stats();
    sched.shutdown();

    assert_eq!(stats.batched_jobs, 1, "sibling paths did not fuse: {stats:?}");
    assert_eq!(stats.batched_fits, 2);

    let mut points: HashMap<u64, Vec<(usize, f64)>> = HashMap::new();
    let mut done: HashMap<u64, usize> = HashMap::new();
    for e in &events {
        match e {
            JobEvent::PathPoint(p) if p.job_id != blocker => {
                assert!(p.converged, "fused point {} of job {} unconverged", p.index, p.job_id);
                points.entry(p.job_id).or_default().push((p.index, p.point.objective));
            }
            JobEvent::PathDone(s) if s.job_id != blocker => {
                assert!(!s.timed_out);
                assert_eq!(s.n_points, ratios.len());
                done.insert(s.job_id, s.n_points);
            }
            other => assert_eq!(other.job_id(), blocker, "unexpected event {}", other.job_id()),
        }
    }
    assert_eq!(done.len(), 2, "both path jobs must terminate: {done:?}");
    for id in [a, b] {
        let mut pts = points.remove(&id).unwrap_or_default();
        pts.sort_by_key(|(i, _)| *i);
        assert_eq!(
            pts.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
            (0..ratios.len()).collect::<Vec<_>>(),
            "job {id} missing points"
        );
        // identical specs advanced in λ-lockstep: member sweeps agree
        if id == b {
            continue;
        }
        let other = &points[&b];
        for ((_, oa), (_, ob)) in pts.iter().zip(other.iter()) {
            assert!(
                (oa - ob).abs() <= 1e-12 * (1.0 + oa.abs()),
                "sibling sweeps diverged: {oa} vs {ob}"
            );
        }
    }
}
