//! End-to-end regression tests for the production fit service: real TCP
//! sockets against [`skglm::coordinator::service`], exercising the
//! robustness contract — typed error frames that never drop the
//! connection, admission-control backpressure with `retry_after_ms`,
//! mid-path cancellation within one λ point, deadline-bounded partial
//! results carrying optimality certificates, client disconnects that
//! free (not wedge) workers, injected worker panics survived by
//! resubmission, and a dead worker pool surfacing as `scheduler_down`.

use skglm::coordinator::service::{spawn, ExitReason, ServiceConfig};
use skglm::coordinator::{ClientConfig, ClientError, FaultPlan, ServiceClient};
use skglm::util::json::Json;
use std::time::Duration;

const EVENT_TIMEOUT: Duration = Duration::from_secs(30);

fn service(faults: &str, workers: usize, max_queue: usize) -> skglm::coordinator::ServiceHandle {
    spawn(ServiceConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        max_queue,
        faults: FaultPlan::parse(faults).expect("test fault plan parses"),
        ..ServiceConfig::default()
    })
    .expect("service binds an ephemeral port")
}

fn client(handle: &skglm::coordinator::ServiceHandle, tenant: &str) -> ServiceClient {
    ServiceClient::connect(ClientConfig {
        addr: handle.addr.to_string(),
        tenant: tenant.to_string(),
        session: format!("itest-{tenant}"),
        retry_seed: 9,
        ..ClientConfig::default()
    })
    .expect("client connects")
}

fn dataset(seed: u64) -> Json {
    Json::obj()
        .with("kind", "correlated")
        .with("n", 40.0)
        .with("p", 60.0)
        .with("seed", seed as f64)
}

fn fit_body(seed: u64) -> Vec<(&'static str, Json)> {
    vec![
        ("kind", Json::Str("fit".to_string())),
        ("model", Json::Str("lasso".to_string())),
        ("lambda_ratio", Json::Num(0.1)),
        ("dataset", dataset(seed)),
    ]
}

fn path_body(seed: u64, count: usize) -> Vec<(&'static str, Json)> {
    vec![
        ("kind", Json::Str("path".to_string())),
        ("model", Json::Str("lasso".to_string())),
        ("grid", Json::obj().with("min_ratio", 0.05).with("count", count as f64)),
        ("dataset", dataset(seed)),
    ]
}

fn job_id(accepted: &Json) -> u64 {
    accepted.get("job").and_then(Json::as_f64).expect("accepted frame carries a job id") as u64
}

fn frame_type(f: &Json) -> &str {
    f.get("type").and_then(Json::as_str).unwrap_or("")
}

#[test]
fn submit_streams_fit_done_with_certificate_and_status_roundtrip() {
    let handle = service("", 2, 8);
    let mut c = client(&handle, "basic");
    let accepted = c.submit(&fit_body(1)).expect("submit accepted");
    let job = job_id(&accepted);
    let (points, terminal) = c.wait_terminal(job, EVENT_TIMEOUT).expect("fit terminates");
    assert!(points.is_empty(), "fit jobs fold their point into fit_done");
    assert_eq!(frame_type(&terminal), "fit_done");
    assert_eq!(terminal.get("outcome").and_then(Json::as_str), Some("ok"));
    let obj = terminal.get("objective").and_then(Json::as_f64).expect("objective present");
    assert!(obj.is_finite());
    assert!(
        terminal.get("certificate").and_then(Json::as_str).is_some(),
        "terminal frame must carry the optimality certificate"
    );
    let status = c.status(job).expect("status of a finished job");
    assert_eq!(status.get("state").and_then(Json::as_str), Some("ok"));
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn malformed_input_gets_typed_errors_and_the_connection_survives() {
    let handle = service("", 1, 8);
    let mut c = client(&handle, "mal");

    // raw garbage framing → parse_error, connection stays up
    c.send_bytes(&[0, 0, 0, 7, b'n', b'o', b't', b'-', b'j', b's', b'o'])
        .expect("send malformed frame");
    let reply = c.recv_any(EVENT_TIMEOUT).expect("typed reply, not a dropped connection");
    assert_eq!(frame_type(&reply), "error");
    assert_eq!(reply.get("code").and_then(Json::as_str), Some("parse_error"));

    // depth bomb → depth_limit
    let mut bomb = (50_000u32).to_be_bytes().to_vec();
    bomb.resize(4 + 50_000, b'[');
    c.send_bytes(&bomb).expect("send depth bomb");
    let reply = c.recv_any(EVENT_TIMEOUT).expect("depth bomb gets a typed reply");
    assert_eq!(reply.get("code").and_then(Json::as_str), Some("depth_limit"));

    // unknown envelope field → unknown_field
    let err = c
        .request("submit", &[("bogus_field", Json::Num(1.0))])
        .expect_err("unknown field must be rejected");
    match err {
        ClientError::Server { code, .. } => assert_eq!(code, "unknown_field"),
        other => panic!("expected a typed server error, got {other}"),
    }

    // out-of-range λ → bad_lambda
    let mut body = fit_body(2);
    body[2] = ("lambda_ratio", Json::Num(1.5));
    match c.submit(&body).expect_err("lambda_ratio 1.5 must be rejected") {
        ClientError::Server { code, .. } => assert_eq!(code, "bad_lambda"),
        other => panic!("expected a typed server error, got {other}"),
    }

    // unknown model → bad_model
    let mut body = fit_body(2);
    body[1] = ("model", Json::Str("ridge".to_string()));
    match c.submit(&body).expect_err("unknown model must be rejected") {
        ClientError::Server { code, .. } => assert_eq!(code, "bad_model"),
        other => panic!("expected a typed server error, got {other}"),
    }

    // unknown precision → bad_precision, not a silent f64 default
    let mut body = fit_body(2);
    body.push(("precision", Json::Str("f16".to_string())));
    match c.submit(&body).expect_err("unknown precision must be rejected") {
        ClientError::Server { code, .. } => assert_eq!(code, "bad_precision"),
        other => panic!("expected a typed server error, got {other}"),
    }

    // unknown isa name → bad_precision ("auto" always passes)
    let mut body = fit_body(2);
    body.push(("isa", Json::Str("warp9".to_string())));
    match c.submit(&body).expect_err("unknown isa must be rejected") {
        ClientError::Server { code, .. } => assert_eq!(code, "bad_precision"),
        other => panic!("expected a typed server error, got {other}"),
    }

    // after all of that the same connection still serves requests
    let pong = c.ping().expect("connection survives every typed rejection");
    assert_eq!(frame_type(&pong), "pong");
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn reduced_precision_submit_fits_end_to_end() {
    let handle = service("", 1, 8);
    let mut c = client(&handle, "prec");
    let mut body = fit_body(3);
    body.push(("precision", Json::Str("mixed".to_string())));
    body.push(("isa", Json::Str("auto".to_string())));
    let acc = c.submit(&body).expect("mixed-precision submit is accepted");
    let job = acc.get("job").and_then(Json::as_f64).expect("accepted frame carries job") as u64;
    let (_points, terminal) = c.wait_terminal(job, EVENT_TIMEOUT).expect("terminal event");
    assert_eq!(frame_type(&terminal), "fit_done");
    assert_eq!(terminal.get("outcome").and_then(Json::as_str), Some("ok"));
    let obj = terminal.get("objective").and_then(Json::as_f64).expect("objective present");
    assert!(obj.is_finite());
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn admission_control_rejects_with_retry_after_then_retry_lands() {
    // 1 worker, queue depth 2, every solve slowed by 200 ms: the third
    // concurrent submit must be rejected with a backoff hint, and the
    // retrying submit path must eventually land once the queue drains.
    let handle = service("slow=200", 1, 2);
    let mut c = client(&handle, "burst");
    let mut live = Vec::new();
    let mut hint = None;
    for seed in 10..20u64 {
        match c.submit(&fit_body(seed)) {
            Ok(accepted) => live.push(job_id(&accepted)),
            Err(ClientError::Server { code, retry_after_ms, .. }) => {
                assert_eq!(code, "rejected");
                hint = retry_after_ms;
                break;
            }
            Err(other) => panic!("unexpected submit failure: {other}"),
        }
    }
    let hint = hint.expect("queue of depth 2 must reject before 10 submits");
    assert!(hint > 0, "rejection must carry a positive retry_after_ms hint");

    let accepted = c.submit_retrying(&fit_body(99)).expect("backoff retry eventually lands");
    live.push(job_id(&accepted));
    for job in live {
        let (_, terminal) = c.wait_terminal(job, EVENT_TIMEOUT).expect("job terminates");
        assert_eq!(frame_type(&terminal), "fit_done");
    }
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn cancelled_path_stops_within_one_lambda_point() {
    // a 32-λ sweep where every point costs ≥150 ms: cancel after the
    // first streamed point and require the job to stop within one point
    let handle = service("slow_seed=777@150", 1, 4);
    let mut c = client(&handle, "cancel");
    let accepted = c.submit(&path_body(777, 32)).expect("path accepted");
    let job = job_id(&accepted);
    let first = c.next_event(EVENT_TIMEOUT).expect("first path point streams");
    assert_eq!(frame_type(&first), "path_point");
    let cancel = c.cancel(job).expect("cancel round-trips");
    assert_eq!(cancel.get("found").and_then(Json::as_bool), Some(true));
    let (points, terminal) = c.wait_terminal(job, EVENT_TIMEOUT).expect("terminal event");
    assert_eq!(frame_type(&terminal), "cancelled");
    let emitted =
        terminal.get("points_emitted").and_then(Json::as_f64).expect("points_emitted") as usize;
    assert!(
        emitted <= 1 + points.len() + 1,
        "cancellation must land within one λ point (emitted {emitted})"
    );
    assert!(emitted < 32, "a cancelled 32-λ path must not run to completion");
    // the freed worker picks up new work promptly
    let accepted = c.submit(&fit_body(3)).expect("fresh submit after cancel");
    let (_, terminal) =
        c.wait_terminal(job_id(&accepted), EVENT_TIMEOUT).expect("fresh fit completes");
    assert_eq!(frame_type(&terminal), "fit_done");
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn deadline_exceeded_returns_partial_points_with_certificates() {
    let handle = service("slow_seed=888@150", 1, 4);
    let mut c = client(&handle, "deadline");
    let mut body = path_body(888, 8);
    body.push(("deadline_ms", Json::Num(500.0)));
    let accepted = c.submit(&body).expect("deadline path accepted");
    let job = job_id(&accepted);
    let (points, terminal) = c.wait_terminal(job, EVENT_TIMEOUT).expect("terminates by deadline");
    assert_eq!(frame_type(&terminal), "path_done");
    assert_eq!(
        terminal.get("outcome").and_then(Json::as_str),
        Some("timeout"),
        "a deadline-cut sweep must be marked outcome:timeout"
    );
    let n_points = terminal.get("n_points").and_then(Json::as_f64).unwrap_or(-1.0) as usize;
    assert_eq!(n_points, points.len(), "summary count matches streamed points");
    assert!(n_points < 8, "500 ms deadline must cut a 8×150 ms sweep short");
    for p in &points {
        let obj = p.get("objective").and_then(Json::as_f64).expect("objective");
        assert!(obj.is_finite(), "partial results must have finite objectives");
        assert!(
            p.get("certificate").and_then(Json::as_str).is_some(),
            "every emitted point carries its optimality certificate"
        );
    }
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn mid_stream_disconnect_frees_the_worker() {
    let handle = service("slow_seed=555@200", 1, 4);
    let ghost = {
        let mut g = client(&handle, "ghost");
        let _ = g.submit(&path_body(555, 16)).expect("ghost path accepted");
        let first = g.next_event(EVENT_TIMEOUT).expect("ghost sees one point");
        assert_eq!(frame_type(&first), "path_point");
        g
    };
    // vanish mid-stream: the server must cancel the orphan, not wedge
    ghost.abandon();

    let mut c = client(&handle, "alive");
    let accepted = c.submit(&fit_body(4)).expect("submit after ghost disconnect");
    let (_, terminal) = c
        .wait_terminal(job_id(&accepted), Duration::from_secs(15))
        .expect("the single worker is freed within one λ point");
    assert_eq!(frame_type(&terminal), "fit_done");
    let stats = c.stats().expect("stats");
    assert_eq!(stats.get("workers_alive").and_then(Json::as_f64), Some(1.0));
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn injected_worker_panic_surfaces_failed_and_resubmit_succeeds() {
    let handle = service("panic_seed=666999", 2, 8);
    let mut c = client(&handle, "panicky");
    let accepted = c.submit(&fit_body(666999)).expect("doomed fit accepted");
    let (_, terminal) =
        c.wait_terminal(job_id(&accepted), EVENT_TIMEOUT).expect("failure is terminal");
    assert_eq!(frame_type(&terminal), "failed");
    let msg = terminal.get("message").and_then(Json::as_str).unwrap_or("");
    assert!(msg.contains("injected"), "panic message preserved, got {msg:?}");
    // the pool survives one panic; a clean resubmit succeeds
    let accepted = c.submit_retrying(&fit_body(5)).expect("resubmit after panic");
    let (_, terminal) = c.wait_terminal(job_id(&accepted), EVENT_TIMEOUT).expect("fit lands");
    assert_eq!(frame_type(&terminal), "fit_done");
    assert_eq!(terminal.get("outcome").and_then(Json::as_str), Some("ok"));
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn tenant_over_budget_gets_a_typed_rejection() {
    let handle = spawn(ServiceConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        max_queue: 8,
        tenant_bytes: Some(100_000),
        ..ServiceConfig::default()
    })
    .expect("service binds");
    let mut c = client(&handle, "hoarder");
    // 40×60 ≈ 19 kB: fits the budget
    let accepted = c.submit(&fit_body(6)).expect("small dataset accepted");
    let (_, terminal) = c.wait_terminal(job_id(&accepted), EVENT_TIMEOUT).expect("fit done");
    assert_eq!(frame_type(&terminal), "fit_done");
    // 200×400 ≈ 640 kB: over the 100 kB tenant budget
    let mut body = fit_body(7);
    body[3] = (
        "dataset",
        Json::obj()
            .with("kind", "correlated")
            .with("n", 200.0)
            .with("p", 400.0)
            .with("seed", 7.0),
    );
    match c.submit(&body).expect_err("oversized tenant dataset must be refused") {
        ClientError::Server { code, .. } => assert_eq!(code, "tenant_budget"),
        other => panic!("expected a typed tenant_budget error, got {other}"),
    }
    // the refusal is not a ban: the tenant can still run within budget
    let pong = c.ping().expect("connection survives the budget rejection");
    assert_eq!(frame_type(&pong), "pong");
    handle.stop();
    assert_eq!(handle.join(), ExitReason::Stopped);
}

#[test]
fn dead_worker_pool_surfaces_scheduler_down_and_nonzero_exit() {
    let handle = service("die_seed=424242", 1, 4);
    let mut c = client(&handle, "doom");
    let accepted = c.submit(&fit_body(424242)).expect("pool-killing submit accepted");
    let (_, terminal) =
        c.wait_terminal(job_id(&accepted), EVENT_TIMEOUT).expect("terminal event arrives");
    assert!(
        matches!(frame_type(&terminal), "scheduler_down" | "failed" | "cancelled"),
        "a dead pool must be loud, got {:?}",
        frame_type(&terminal)
    );
    assert_eq!(
        handle.join(),
        ExitReason::SchedulerDown,
        "service exit must report the dead worker pool"
    );
}
