//! Integration: group penalties and multitask fits as first-class
//! scheduler jobs — warm path sweeps through the block-coordinate engine,
//! multitask-via-scheduler vs direct-solve equivalence, and gap-safe
//! block screening soundness end-to-end.

use skglm::coordinator::{specs, FitScheduler, JobEvent};
use skglm::data::{grouped_correlated, Dataset, GroupedSpec};
use skglm::estimators::group_lambda_max;
use skglm::estimators::path::geometric_grid;
use skglm::solver::{solve_multitask, SolverOpts};
use std::sync::Arc;

#[test]
fn group_lasso_path_streams_through_the_scheduler_with_screening() {
    let (ds, part) = grouped_correlated(
        GroupedSpec { n: 100, p: 80, group_size: 8, active_groups: 2, rho: 0.5, snr: 8.0 },
        3,
    );
    let ds = Arc::new(ds);
    let ratios = geometric_grid(1e-2, 6);
    let sched = FitScheduler::start(1);
    let job = sched.submit_path(
        Arc::clone(&ds),
        specs::group_lasso(1.0, Arc::clone(&part)),
        ratios.clone(),
        SolverOpts::default().with_tol(1e-9),
    );
    let events = sched.collect_events(ratios.len() + 1);
    sched.shutdown();

    let mut points = Vec::new();
    for e in events {
        match e {
            JobEvent::PathPoint(p) => {
                assert_eq!(p.job_id, job);
                points.push(p);
            }
            JobEvent::PathDone(s) => assert_eq!(s.n_points, ratios.len()),
            JobEvent::Failed { job_id, message } => {
                panic!("group path job {job_id} failed: {message}")
            }
            JobEvent::FitDone(_) => panic!("unexpected fit event"),
            other => panic!("unexpected terminal event for job {}", other.job_id()),
        }
    }
    assert_eq!(points.len(), ratios.len());
    points.sort_by_key(|p| p.index);
    // λ_max anchors the grid: the first point is (near-)empty, support
    // grows down the path, and every point matches a direct solve
    assert_eq!(points[0].point.support_size, 0, "support empty at lambda_max");
    assert!(points.last().unwrap().point.support_size >= points[0].point.support_size);
    for p in &points {
        let direct = skglm::estimators::group::group_lasso(p.point.lambda, Arc::clone(&part))
            .with_tol(1e-9)
            .fit(&ds.design, &ds.y);
        assert!(
            p.point.objective <= direct.result.objective + 1e-7,
            "warm path point worse than direct solve at ratio {}: {} vs {}",
            p.point.lambda_ratio,
            p.point.objective,
            direct.result.objective
        );
    }
}

#[test]
fn group_screening_certifies_blocks_without_changing_the_optimum() {
    use skglm::penalty::GroupLasso;
    use skglm::solver::solve_blocks;
    let (ds, part) = grouped_correlated(
        GroupedSpec { n: 120, p: 90, group_size: 6, active_groups: 2, rho: 0.4, snr: 10.0 },
        7,
    );
    let lam = group_lambda_max(&ds.design, &ds.y, &part, None) / 3.0;
    // screened spec solve vs a raw UNSCREENED engine solve (the
    // estimator constructor screens too, so go through solve_blocks)
    let spec = specs::group_lasso(lam, Arc::clone(&part));
    let mut state = skglm::solver::ContinuationState::default();
    let screened = spec.solve(
        &ds.design,
        &ds.y,
        &SolverOpts::default().with_tol(1e-10),
        &mut state,
        None,
        None,
    );
    let mut datafit = skglm::datafit::GroupedQuadratic::new(Arc::clone(&part));
    let plain = solve_blocks(
        &ds.design,
        &ds.y,
        &part,
        &mut datafit,
        &GroupLasso::new(lam),
        &SolverOpts::default().with_tol(1e-10),
        None,
    );
    assert_eq!(plain.n_screened, 0, "raw solve_blocks must not screen");
    assert!(
        (screened.objective - plain.objective).abs() < 1e-9,
        "screened {} vs plain {}",
        screened.objective,
        plain.objective
    );
    for (a, b) in screened.beta.iter().zip(plain.v.iter()) {
        assert!((a - b).abs() < 1e-7, "{a} vs {b}");
    }
}

fn multitask_dataset(seed: u64) -> (Arc<Dataset>, usize) {
    let pb = skglm::data::meeg::simulate(
        skglm::data::meeg::MeegSpec { n_sensors: 30, n_sources: 70, n_times: 6, ..Default::default() },
        seed,
    );
    let t = pb.measurements.ncols();
    let y = skglm::estimators::multitask::flatten_tasks(&pb.measurements);
    let ds = Dataset {
        name: format!("meeg-{seed}"),
        design: skglm::linalg::Design::Dense(pb.gain.clone()),
        y,
        beta_true: Vec::new(),
    };
    (Arc::new(ds), t)
}

#[test]
fn multitask_via_scheduler_equals_direct_solve() {
    let (ds, t) = multitask_dataset(11);
    let lam =
        skglm::estimators::multitask::block_lambda_max(&ds.design, &ds.y, t) / 4.0;
    let opts = SolverOpts::default().with_tol(1e-9);

    let direct =
        solve_multitask(&ds.design, &ds.y, t, &skglm::penalty::BlockL21::new(lam), &opts);

    let sched = FitScheduler::start(1);
    sched.submit_fit(
        Arc::clone(&ds),
        specs::multitask_l21(lam, ds.design.ncols(), t),
        opts.clone(),
    );
    let outcomes = sched.collect_fits(1);
    sched.shutdown();
    let via_sched = &outcomes[0].result;

    assert!(via_sched.converged && direct.converged);
    assert!(
        (via_sched.objective - direct.objective).abs() < 1e-12,
        "scheduler {} vs direct {}",
        via_sched.objective,
        direct.objective
    );
    assert_eq!(via_sched.beta.len(), direct.w.len());
    for (a, b) in via_sched.beta.iter().zip(direct.w.iter()) {
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
    assert_eq!(outcomes[0].label, "quadratic_multitask/l21");
}

#[test]
fn multitask_path_sweeps_warm_through_the_scheduler() {
    let (ds, t) = multitask_dataset(13);
    let ratios = geometric_grid(5e-2, 5);
    let sched = FitScheduler::start(1);
    sched.submit_path(
        Arc::clone(&ds),
        specs::multitask_l21(1.0, ds.design.ncols(), t),
        ratios.clone(),
        SolverOpts::default().with_tol(1e-8),
    );
    let events = sched.collect_events(ratios.len() + 1);
    sched.shutdown();
    let mut n_points = 0;
    let mut last_support = 0;
    for e in &events {
        match e {
            JobEvent::PathPoint(p) => {
                n_points += 1;
                last_support = p.point.support_size;
            }
            JobEvent::PathDone(_) => {}
            JobEvent::Failed { job_id, message } => {
                panic!("multitask path job {job_id} failed: {message}")
            }
            JobEvent::FitDone(_) => panic!("unexpected fit event"),
            other => panic!("unexpected terminal event for job {}", other.job_id()),
        }
    }
    assert_eq!(n_points, ratios.len());
    assert!(last_support > 0, "densest λ point should have active rows");
}

#[test]
fn group_mcp_spec_is_sparser_than_group_lasso_at_same_lambda() {
    let (ds, part) = grouped_correlated(
        GroupedSpec { n: 150, p: 100, group_size: 10, active_groups: 2, rho: 0.5, snr: 8.0 },
        17,
    );
    let lam = group_lambda_max(&ds.design, &ds.y, &part, None) / 6.0;
    let opts = SolverOpts::default().with_tol(1e-8);
    let lasso = skglm::estimators::group::group_lasso(lam, Arc::clone(&part))
        .with_tol(1e-8)
        .fit(&ds.design, &ds.y);
    // γ > 1/min L_b: AR(1) columns have ‖X_j‖² ≈ n so L_b ≈ group size
    let mcp = skglm::estimators::group::GroupEstimator::from_parts(
        skglm::penalty::GroupMcp::new(lam, 3.0),
        Arc::clone(&part),
        opts,
    )
    .fit(&ds.design, &ds.y);
    assert!(mcp.result.converged, "kkt {}", mcp.result.kkt);
    assert!(
        mcp.group_support().len() <= lasso.group_support().len(),
        "group MCP ({}) should be at least as group-sparse as group Lasso ({})",
        mcp.group_support().len(),
        lasso.group_support().len()
    );
}
