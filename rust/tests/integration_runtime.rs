//! Integration: the PJRT runtime path — load AOT artifacts, execute them,
//! and verify they agree with the native Rust path end to end.
//!
//! Requires `make artifacts` (skips gracefully when artifacts are absent,
//! so `cargo test` stays green on a fresh checkout).

use skglm::data::{correlated, CorrelatedSpec};
use skglm::datafit::{Datafit, Quadratic};
use skglm::linalg::Design;
use skglm::penalty::L1;
use skglm::runtime::{PjrtGradEngine, PjrtRuntime};
use skglm::solver::{solve, GradEngine, SolverOpts};

const N: usize = 200;
const P: usize = 400;

fn have_artifacts() -> bool {
    PjrtRuntime::available("xt_r", N, P)
}

fn test_problem() -> skglm::data::Dataset {
    correlated(CorrelatedSpec { n: N, p: P, rho: 0.5, nnz: 20, snr: 8.0 }, 1234)
}

#[test]
fn pjrt_grad_matches_native_grad() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ds = test_problem();
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let mut engine = PjrtGradEngine::for_design(&rt, &ds.design).expect("engine");

    let mut datafit = Quadratic::new();
    datafit.init(&ds.design, &ds.y);
    let beta: Vec<f64> = (0..P).map(|j| if j % 17 == 0 { 0.5 } else { 0.0 }).collect();
    let state = datafit.init_state(&ds.design, &ds.y, &beta);

    let mut native = vec![0.0; P];
    datafit.grad_full(&ds.design, &ds.y, &state, &beta, &mut native);
    let mut via_pjrt = vec![0.0; P];
    assert!(engine.grad_full(&ds.design, &ds.y, &state, &beta, &mut via_pjrt));
    assert_eq!(engine.calls, 1);

    let scale = native.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for j in 0..P {
        assert!(
            (native[j] - via_pjrt[j]).abs() <= 1e-5 * scale,
            "grad[{j}]: native {} vs pjrt {}",
            native[j],
            via_pjrt[j]
        );
    }
}

#[test]
fn solver_with_pjrt_engine_reaches_same_optimum() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ds = test_problem();
    let lam = skglm::estimators::Lasso::lambda_max(&ds.design, &ds.y) / 20.0;
    let pen = L1::new(lam);
    // f32 scoring: stay above the engine's precision floor
    let opts = SolverOpts::default().with_tol(PjrtGradEngine::MIN_TOL);

    let mut f_native = Quadratic::new();
    let native = solve(&ds.design, &ds.y, &mut f_native, &pen, &opts, None, None);

    let rt = PjrtRuntime::cpu().unwrap();
    let mut engine = PjrtGradEngine::for_design(&rt, &ds.design).unwrap();
    let mut f_pjrt = Quadratic::new();
    let via_pjrt = solve(
        &ds.design,
        &ds.y,
        &mut f_pjrt,
        &pen,
        &opts,
        Some(&mut engine as &mut dyn GradEngine),
        None,
    );
    assert!(engine.calls > 0, "engine must actually serve scoring passes");
    assert!(via_pjrt.converged, "kkt {}", via_pjrt.kkt);
    assert!(
        (native.objective - via_pjrt.objective).abs() <= 1e-8 * native.objective.abs().max(1.0),
        "objectives diverge: native {} vs pjrt {}",
        native.objective,
        via_pjrt.objective
    );
    assert_eq!(native.support(), via_pjrt.support());
}

#[test]
fn engine_rejects_mismatched_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ds = test_problem();
    let rt = PjrtRuntime::cpu().unwrap();
    let mut engine = PjrtGradEngine::for_design(&rt, &ds.design).unwrap();
    // wrong-shape problem: engine must decline, not crash
    let other = correlated(CorrelatedSpec { n: 50, p: 60, rho: 0.3, nnz: 5, snr: 5.0 }, 5);
    let mut out = vec![0.0; 60];
    let state = vec![0.0; 50];
    assert!(!engine.grad_full(&other.design, &other.y, &state, &[], &mut out));
}

#[test]
fn engine_refuses_sparse_designs() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let sparse: Design =
        skglm::linalg::CscMatrix::from_triplets(N, P, &[(0, 0, 1.0)]).into();
    assert!(PjrtGradEngine::for_design(&rt, &sparse).is_err());
}

#[test]
fn fused_score_artifact_matches_native_scores() {
    if !PjrtRuntime::available("score_l1", N, P) {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let ds = test_problem();
    let dense = match &ds.design {
        Design::Dense(m) => m,
        _ => unreachable!(),
    };
    let rt = PjrtRuntime::cpu().unwrap();
    let artifact = rt.load("score_l1", N, P).expect("load fused score artifact");

    let mut datafit = Quadratic::new();
    datafit.init(&ds.design, &ds.y);
    let beta: Vec<f64> = (0..P).map(|j| if j % 23 == 0 { -0.3 } else { 0.0 }).collect();
    let state = datafit.init_state(&ds.design, &ds.y, &beta);
    let lam = 0.05f64;

    // native scores
    let mut grad = vec![0.0; P];
    datafit.grad_full(&ds.design, &ds.y, &state, &beta, &mut grad);
    let pen = L1::new(lam);
    use skglm::penalty::Penalty;
    let native_scores: Vec<f64> =
        (0..P).map(|j| pen.subdiff_distance(beta[j], grad[j], j)).collect();

    // fused artifact: inputs xt[p,n], r[n], beta[p], lam[1] → (grad, score)
    let xt = skglm::runtime::client::literal_from_f64(dense.raw(), &[P, N]).unwrap();
    let r = skglm::runtime::client::literal_from_f64(&state, &[N]).unwrap();
    let b = skglm::runtime::client::literal_from_f64(&beta, &[P]).unwrap();
    let l = skglm::runtime::client::literal_from_f64(&[lam], &[1]).unwrap();
    let result = artifact.run_tuple(&[xt, r, b, l]).expect("execute");
    assert_eq!(result.len(), 2, "fused kernel returns (grad, score)");
    let scores = &result[1];
    let scale = native_scores.iter().fold(1.0f64, |m, v| m.max(*v));
    for j in 0..P {
        assert!(
            (native_scores[j] - scores[j] as f64).abs() <= 2e-5 * scale,
            "score[{j}]: native {} vs fused {}",
            native_scores[j],
            scores[j]
        );
    }
    // grad part too
    for j in 0..P {
        assert!((grad[j] - result[0][j] as f64).abs() <= 2e-5 * scale);
    }
}
