//! Integration: the benchopt-like harness + figure runners end to end
//! (smoke scale), checking the paper's qualitative claims hold on the
//! generated outputs, plus the coordinator service under load.

use skglm::bench::figures::{run_experiment, Scale};
use skglm::bench::harness::{black_box_curve, budget_schedule};
use skglm::data::{correlated, CorrelatedSpec};
use skglm::datafit::Quadratic;
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::penalty::L1;
use skglm::solver::{solve, SolverOpts};

struct TmpResults(std::path::PathBuf);

impl TmpResults {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("skglm_it_{tag}_{}", std::process::id()));
        std::env::set_var("SKGLM_RESULTS", &dir);
        Self(dir)
    }
}

impl Drop for TmpResults {
    fn drop(&mut self) {
        std::env::remove_var("SKGLM_RESULTS");
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// The paper's central claim at smoke scale: with working sets + Anderson,
/// skglm reaches a tight gap no slower (in CD-epoch budget terms) than
/// plain full CD — and usually much faster.
#[test]
fn skglm_beats_full_cd_on_epoch_budgets() {
    let ds = correlated(CorrelatedSpec { n: 150, p: 500, rho: 0.5, nnz: 15, snr: 8.0 }, 21);
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / 100.0;
    let pen = L1::new(lam);
    let tol = 1e-10;

    let mut f1 = Quadratic::new();
    let sk = solve(&ds.design, &ds.y, &mut f1, &pen, &SolverOpts::default().with_tol(tol), None, None);
    let mut f2 = Quadratic::new();
    let mut opts = SolverOpts::default().with_tol(tol).without_ws().without_acceleration();
    opts.max_epochs = 200_000;
    let cd = solve(&ds.design, &ds.y, &mut f2, &pen, &opts, None, None);

    assert!(sk.converged && cd.converged);
    // epochs are ws-restricted for skglm, full-p for CD: compare the
    // coordinate-update count (epochs × sweep width ≈ n_epochs * |ws|
    // vs n_epochs * p). History records ws sizes; a coarse but robust
    // proxy: skglm needs fewer epochs, each over fewer coordinates.
    assert!(
        sk.n_epochs <= cd.n_epochs,
        "skglm epochs {} vs full CD {}",
        sk.n_epochs,
        cd.n_epochs
    );
}

#[test]
fn harness_budgets_drive_metric_down() {
    let ds = correlated(CorrelatedSpec { n: 80, p: 160, rho: 0.5, nnz: 8, snr: 8.0 }, 22);
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / 50.0;
    let pen = L1::new(lam);
    let budgets = budget_schedule(32, 1.8);
    let curve = black_box_curve("skglm", &budgets, |b| {
        let mut f = Quadratic::new();
        let mut opts = SolverOpts::default().with_tol(1e-14);
        opts.max_outer = b;
        let r = solve(&ds.design, &ds.y, &mut f, &pen, &opts, None, None);
        let mut xb = vec![0.0; ds.n()];
        ds.design.matvec(&r.beta, &mut xb);
        let resid: Vec<f64> = ds.y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect();
        (r.objective, skglm::metrics::lasso_gap(&ds.design, &ds.y, &r.beta, &resid, lam))
    });
    let first = curve.points.first().unwrap().metric;
    let last = curve.points.last().unwrap().metric;
    assert!(last < first * 1e-3, "gap must collapse: {first} -> {last}");
    // envelope is monotone
    let env = curve.monotone_envelope();
    for w in env.windows(2) {
        assert!(w[1].1 <= w[0].1);
    }
}

#[test]
fn fig6_ablation_orders_solvers_correctly() {
    let _tmp = TmpResults::new("fig6");
    let out = run_experiment("fig6", Scale::Smoke).expect("fig6");
    assert!(!out.is_empty());
    // parse a CSV and check ws_accel reaches the best gap within the
    // total budget
    let csv = std::fs::read_to_string(&out[0]).unwrap();
    let mut best: std::collections::HashMap<String, f64> = Default::default();
    for line in csv.lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        let solver = cells[0].to_string();
        let metric: f64 = cells[4].parse().unwrap();
        let e = best.entry(solver).or_insert(f64::INFINITY);
        *e = e.min(metric);
    }
    let ws_accel = best["ws_accel"];
    let no_ws_no_accel = best["no_ws_no_accel"];
    assert!(
        ws_accel <= no_ws_no_accel * 10.0,
        "ws+accel ({ws_accel:.2e}) should be in the same class or better than plain CD ({no_ws_no_accel:.2e})"
    );
}

#[test]
fn fig4_block_mcp_localizes_both_hemispheres() {
    let _tmp = TmpResults::new("fig4");
    let out = run_experiment("fig4", Scale::Smoke).expect("fig4");
    let md = std::fs::read_to_string(&out[0]).unwrap();
    // every block_mcp row must hit 2 hemispheres
    for line in md.lines().filter(|l| l.contains("block_mcp")) {
        let cells: Vec<&str> = line.split('|').map(|c| c.trim()).collect();
        assert_eq!(cells[4], "2", "block_mcp must find both sources: {line}");
    }
}

#[test]
fn table1_and_fig10_emit() {
    let _tmp = TmpResults::new("t1");
    let out = run_experiment("table1", Scale::Smoke).unwrap();
    let md = std::fs::read_to_string(&out[0]).unwrap();
    assert!(md.contains("skglm-rs (ours)"));
    let out = run_experiment("fig10", Scale::Smoke).unwrap();
    assert!(out[0].exists());
}

#[test]
fn coordinator_scheduler_parallel_sweep_matches_serial() {
    use skglm::coordinator::{specs, FitScheduler};
    use std::sync::Arc;
    let ds = Arc::new(correlated(CorrelatedSpec { n: 60, p: 90, rho: 0.4, nnz: 6, snr: 10.0 }, 23));
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let lambdas: Vec<f64> = (1..=5).map(|k| lam_max / (4.0 * k as f64)).collect();

    let sched = FitScheduler::start(3);
    for &lam in &lambdas {
        sched.submit_fit(Arc::clone(&ds), specs::lasso(lam), SolverOpts::default().with_tol(1e-10));
    }
    let mut outcomes = sched.collect_fits(lambdas.len());
    sched.shutdown();
    outcomes.sort_by_key(|o| o.job_id);

    for (k, o) in outcomes.iter().enumerate() {
        let serial = skglm::estimators::Lasso::new(lambdas[k]).with_tol(1e-10).fit(&ds.design, &ds.y);
        assert!(
            (o.result.objective - serial.objective).abs() < 1e-9,
            "job {k} diverges from serial"
        );
    }
}
