//! Property-based tests (in-tree quickcheck driver — proptest is
//! unavailable offline) on the coordinator/solver invariants:
//! prox optimality, score–KKT equivalence, CD descent, working-set
//! monotone growth, Anderson safety, gap soundness.

use skglm::data::{correlated, CorrelatedSpec};
use skglm::datafit::{Datafit, Quadratic};
use skglm::linalg::Design;
use skglm::penalty::{soft_threshold, L1L2, Lq, Mcp, Penalty, Scad, L1};
use skglm::solver::{solve, SolverOpts};
use skglm::util::quickcheck::{check, close, ensure};
use skglm::util::rng::Rng;

const CASES: usize = 60;

/// Random (v, step) prox probe for each penalty family; property:
/// prox output beats a cloud of random candidates on the prox objective.
#[test]
fn prop_prox_minimizes_objective_all_penalties() {
    #[derive(Debug, Clone)]
    struct Probe {
        v: f64,
        step: f64,
        lam: f64,
        gamma: f64,
        candidates: Vec<f64>,
    }
    check(
        1,
        CASES,
        |rng: &mut Rng| Probe {
            v: rng.uniform_range(-6.0, 6.0),
            step: rng.uniform_range(0.05, 1.5),
            lam: rng.uniform_range(0.01, 2.0),
            gamma: rng.uniform_range(2.5, 8.0),
            candidates: (0..200).map(|_| rng.uniform_range(-12.0, 12.0)).collect(),
        },
        |pr| {
            let pens: Vec<(String, Box<dyn Fn(f64, f64) -> f64>, Box<dyn Fn(f64) -> f64>)> = vec![
                {
                    let p = L1::new(pr.lam);
                    let p2 = p.clone();
                    ("l1".into(), Box::new(move |v, s| p.prox(v, s, 0)), Box::new(move |x| p2.value(x, 0)))
                },
                {
                    let p = L1L2::new(pr.lam, 0.5);
                    let p2 = p.clone();
                    ("enet".into(), Box::new(move |v, s| p.prox(v, s, 0)), Box::new(move |x| p2.value(x, 0)))
                },
                {
                    let p = Mcp::new(pr.lam, pr.gamma);
                    let p2 = p.clone();
                    ("mcp".into(), Box::new(move |v, s| p.prox(v, s, 0)), Box::new(move |x| p2.value(x, 0)))
                },
                {
                    let p = Scad::new(pr.lam, pr.gamma.max(3.0));
                    let p2 = p.clone();
                    ("scad".into(), Box::new(move |v, s| p.prox(v, s, 0)), Box::new(move |x| p2.value(x, 0)))
                },
                {
                    let p = Lq::half(pr.lam);
                    let p2 = p.clone();
                    ("l05".into(), Box::new(move |v, s| p.prox(v, s, 0)), Box::new(move |x| p2.value(x, 0)))
                },
            ];
            for (name, prox, value) in &pens {
                let x = prox(pr.v, pr.step);
                let obj = |z: f64| 0.5 * (z - pr.v) * (z - pr.v) + pr.step * value(z);
                let ox = obj(x);
                for &c in &pr.candidates {
                    ensure(
                        ox <= obj(c) + 1e-7,
                        format!("{name}: prox({}, {}) = {x} beaten by {c}", pr.v, pr.step),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// score^∂ == 0  ⟺  the prox fixed-point equation holds (KKT), for the
/// α-semi-convex penalties.
#[test]
fn prop_score_zero_iff_prox_fixed_point() {
    #[derive(Debug, Clone)]
    struct Probe {
        beta: f64,
        grad: f64,
        lam: f64,
        step: f64,
    }
    check(
        2,
        CASES,
        |rng: &mut Rng| Probe {
            beta: if rng.bernoulli(0.4) { 0.0 } else { rng.uniform_range(-4.0, 4.0) },
            grad: rng.uniform_range(-3.0, 3.0),
            lam: rng.uniform_range(0.05, 1.5),
            step: rng.uniform_range(0.1, 1.0),
        },
        |pr| {
            let pens: Vec<Box<dyn Fn() -> (f64, f64)>> = vec![
                {
                    let p = L1::new(pr.lam);
                    let (b, g, s) = (pr.beta, pr.grad, pr.step);
                    Box::new(move || {
                        (p.subdiff_distance(b, g, 0), (b - p.prox(b - s * g, s, 0)).abs())
                    })
                },
                {
                    let p = Mcp::new(pr.lam, 3.0);
                    let (b, g, s) = (pr.beta, pr.grad, pr.step);
                    Box::new(move || {
                        (p.subdiff_distance(b, g, 0), (b - p.prox(b - s * g, s, 0)).abs())
                    })
                },
            ];
            for f in &pens {
                let (score, fp_violation) = f();
                if score < 1e-12 {
                    ensure(
                        fp_violation < 1e-9,
                        format!("score 0 but fixed-point violation {fp_violation}"),
                    )?;
                }
                if fp_violation < 1e-12 {
                    ensure(
                        score < 1e-9,
                        format!("fixed point but score {score}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// Soft-threshold algebra: shrinkage, sign preservation, 1-Lipschitz.
#[test]
fn prop_soft_threshold_algebra() {
    check(
        3,
        200,
        |rng: &mut Rng| (rng.uniform_range(-10.0, 10.0), rng.uniform_range(-10.0, 10.0), rng.uniform_range(0.0, 5.0)),
        |&(a, b, t)| {
            let sa = soft_threshold(a, t);
            let sb = soft_threshold(b, t);
            ensure(sa.abs() <= a.abs() + 1e-15, "shrinks magnitude")?;
            ensure(sa == 0.0 || sa.signum() == a.signum(), "preserves sign")?;
            ensure((sa - sb).abs() <= (a - b).abs() + 1e-12, "1-Lipschitz")?;
            Ok(())
        },
    );
}

/// Full solve invariants on random Lasso instances: monotone history,
/// working sets grow, gap bounds hold, extrapolation never hurts.
#[test]
fn prop_solver_invariants_random_lasso() {
    #[derive(Debug, Clone)]
    struct Instance {
        seed: u64,
        n: usize,
        p: usize,
        lam_div: f64,
    }
    check(
        4,
        12,
        |rng: &mut Rng| Instance {
            seed: rng.next_u64(),
            n: 30 + rng.below(60),
            p: 20 + rng.below(120),
            lam_div: 2.0 + rng.uniform() * 40.0,
        },
        |inst| {
            let ds = correlated(
                CorrelatedSpec {
                    n: inst.n,
                    p: inst.p,
                    rho: 0.4,
                    nnz: (inst.p / 10).max(1),
                    snr: 8.0,
                },
                inst.seed,
            );
            let lam =
                skglm::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y) / inst.lam_div;
            let mut f = Quadratic::new();
            let res = solve(
                &ds.design,
                &ds.y,
                &mut f,
                &L1::new(lam),
                &SolverOpts::default().with_tol(1e-9),
                None,
                None,
            );
            ensure(res.converged, format!("did not converge: kkt {}", res.kkt))?;
            // objective decreases along history
            for w in res.history.windows(2) {
                ensure(
                    w[1].objective <= w[0].objective + 1e-10,
                    format!("objective rose {} -> {}", w[0].objective, w[1].objective),
                )?;
                ensure(w[1].ws_size >= w[0].ws_size, "working set shrank")?;
            }
            // duality-gap certificate at the solution
            let mut xb = vec![0.0; ds.n()];
            ds.design.matvec(&res.beta, &mut xb);
            let r: Vec<f64> =
                ds.y.iter().zip(xb.iter()).map(|(a, b)| a - b).collect();
            let gap = skglm::metrics::lasso_gap(&ds.design, &ds.y, &res.beta, &r, lam);
            ensure(gap <= 1e-6, format!("gap {gap} too large at optimum"))?;
            // KKT certificate coordinatewise
            let mut fq = Quadratic::new();
            fq.init(&ds.design, &ds.y);
            let state = fq.init_state(&ds.design, &ds.y, &res.beta);
            let pen = L1::new(lam);
            let s = skglm::metrics::stationarity(&ds.design, &ds.y, &fq, &pen, &res.beta, &state);
            ensure(s <= 1e-8, format!("stationarity {s}"))?;
            Ok(())
        },
    );
}

/// MCP objective from skglm is never worse than plain CD from the same
/// start (both reach critical points; skglm's must be at least as good
/// because it contains CD as a special case and only accepts descent).
#[test]
fn prop_anderson_guard_never_worsens_mcp() {
    check(
        5,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let ds = correlated(
                CorrelatedSpec { n: 80, p: 120, rho: 0.4, nnz: 10, snr: 8.0 },
                seed,
            );
            let mut design = ds.design.clone();
            design.normalize_cols((80.0f64).sqrt());
            let lam =
                skglm::estimators::linear::quadratic_lambda_max(&design, &ds.y) / 8.0;
            let pen = Mcp::new(lam, 3.0);
            let run = |m: usize| {
                let mut f = Quadratic::new();
                let mut opts = SolverOpts::default().with_tol(1e-9).without_ws();
                opts.anderson_m = m;
                opts.max_epochs = 50_000;
                solve(&design, &ds.y, &mut f, &pen, &opts, None, None)
            };
            let plain = run(0);
            let accel = run(5);
            // same deterministic path + guard ⇒ acceleration can only help
            close(accel.objective, plain.objective, 1e-6).or_else(|_| {
                ensure(
                    accel.objective < plain.objective,
                    format!("accel {} worse than plain {}", accel.objective, plain.objective),
                )
            })
        },
    );
}

/// Sparse == dense solve on the same matrix.
#[test]
fn prop_sparse_dense_equivalence() {
    check(
        6,
        10,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::seed_from_u64(seed);
            let (n, p) = (40, 60);
            let mut rows = Vec::new();
            let mut trips = Vec::new();
            for i in 0..n {
                let mut row = vec![0.0; p];
                for j in 0..p {
                    if rng.bernoulli(0.15) {
                        let v = rng.normal();
                        row[j] = v;
                        trips.push((i, j, v));
                    }
                }
                rows.push(row);
            }
            let dense: Design = skglm::linalg::DenseMatrix::from_rows(&rows).into();
            let sparse: Design = skglm::linalg::CscMatrix::from_triplets(n, p, &trips).into();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let lam = skglm::estimators::linear::quadratic_lambda_max(&dense, &y) / 10.0;
            let pen = L1::new(lam);
            let mut f1 = Quadratic::new();
            let a = solve(&dense, &y, &mut f1, &pen, &SolverOpts::default().with_tol(1e-11), None, None);
            let mut f2 = Quadratic::new();
            let b = solve(&sparse, &y, &mut f2, &pen, &SolverOpts::default().with_tol(1e-11), None, None);
            close(a.objective, b.objective, 1e-9)?;
            for (x, z) in a.beta.iter().zip(b.beta.iter()) {
                close(*x, *z, 1e-7)?;
            }
            Ok(())
        },
    );
}

/// λ ↦ support size is (weakly) monotone along warm-started paths and the
/// objective is monotone in λ.
#[test]
fn prop_path_monotonicity() {
    check(
        7,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let ds = correlated(
                CorrelatedSpec { n: 60, p: 100, rho: 0.4, nnz: 8, snr: 10.0 },
                seed,
            );
            let ratios = skglm::estimators::path::geometric_grid(0.02, 8);
            let path = skglm::estimators::path::lasso_path(
                &ds.design,
                &ds.y,
                None,
                &ratios,
                &SolverOpts::default().with_tol(1e-10),
            );
            // datafit part of the objective decreases as λ decreases
            let mut f = Quadratic::new();
            f.init(&ds.design, &ds.y);
            let datafit_vals: Vec<f64> = path
                .points
                .iter()
                .map(|pt| {
                    let state = f.init_state(&ds.design, &ds.y, &pt.beta);
                    f.value(&ds.y, &pt.beta, &state)
                })
                .collect();
            for w in datafit_vals.windows(2) {
                ensure(
                    w[1] <= w[0] + 1e-9,
                    format!("datafit rose along path: {} -> {}", w[0], w[1]),
                )?;
            }
            Ok(())
        },
    );
}

/// Kernel-engine equivalence (ISSUE 2): the blocked/parallel `Xᵀr`,
/// subset `Xᵀr` and column-norm kernels agree with the serial per-column
/// reference to 1e-12 on random dense AND sparse designs, including
/// remainder shapes (n, p not multiples of the 8-column panel) and the
/// empty / one-column edge cases.
#[test]
fn prop_kernel_engine_matches_serial_reference() {
    #[derive(Debug, Clone)]
    struct Probe {
        n: usize,
        p: usize,
        dense: bool,
        threads: usize,
        seed: u64,
    }
    check(
        11,
        40,
        |rng: &mut Rng| Probe {
            // 0 and 1 included: empty designs and single columns
            n: rng.below(40),
            p: rng.below(45),
            dense: rng.bernoulli(0.5),
            threads: 1 + rng.below(5),
            seed: rng.next_u64(),
        },
        |pr| {
            let mut rng = Rng::seed_from_u64(pr.seed);
            let design: Design = if pr.dense {
                let data: Vec<f64> = (0..pr.n * pr.p).map(|_| rng.normal()).collect();
                skglm::linalg::DenseMatrix::from_col_major(pr.n, pr.p, data).into()
            } else {
                let mut trips = Vec::new();
                for j in 0..pr.p {
                    for i in 0..pr.n {
                        if rng.bernoulli(0.3) {
                            trips.push((i, j, rng.normal()));
                        }
                    }
                }
                skglm::linalg::CscMatrix::from_triplets(pr.n, pr.p, &trips).into()
            };
            let r: Vec<f64> = (0..pr.n).map(|_| rng.normal()).collect();

            // serial per-column reference
            let reference: Vec<f64> =
                (0..pr.p).map(|j| design.col_dot(j, &r)).collect();

            // blocked (1 thread) and parallel variants
            for threads in [1usize, pr.threads] {
                let mut out = vec![0.0; pr.p];
                design.matvec_t_threads(&r, &mut out, threads);
                for j in 0..pr.p {
                    close(out[j], reference[j], 1e-12)?;
                }
            }

            // subset pass over a random working set (with repeats allowed)
            let ws: Vec<usize> =
                (0..pr.p.min(13)).map(|_| rng.below(pr.p.max(1))).collect();
            if pr.p > 0 {
                let mut out = vec![0.0; ws.len()];
                design.matvec_t_subset(&r, &ws, &mut out);
                for (k, &j) in ws.iter().enumerate() {
                    close(out[k], reference[j], 1e-12)?;
                }
            }

            // column norms
            let mut norms = vec![0.0; pr.p];
            design.col_sq_norms_threads(&mut norms, pr.threads);
            for j in 0..pr.p {
                let expect: f64 = match &design {
                    Design::Dense(m) => m.col(j).iter().map(|v| v * v).sum(),
                    Design::Sparse(m) => {
                        let (_, vals) = m.col(j);
                        vals.iter().map(|v| v * v).sum()
                    }
                };
                close(norms[j], expect, 1e-12)?;
            }
            Ok(())
        },
    );
}

/// Parallel `normalize_cols` preserves the serial semantics: returned
/// scales match and every nonzero column lands on the target norm.
#[test]
fn prop_parallel_normalize_cols_hits_target() {
    check(
        13,
        20,
        |rng: &mut Rng| (1 + rng.below(30), 1 + rng.below(35), rng.next_u64()),
        |&(n, p, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            // a zero column when p allows it (edge case: left untouched)
            let zero_col = if p > 1 { Some(p - 1) } else { None };
            let data: Vec<f64> = (0..n * p)
                .map(|k| if Some(k / n) == zero_col { 0.0 } else { rng.normal() })
                .collect();
            let mut design: Design =
                skglm::linalg::DenseMatrix::from_col_major(n, p, data).into();
            let target = (n as f64).sqrt();
            let scales = design.normalize_cols(target);
            ensure(scales.len() == p, "scales length")?;
            let norms = design.col_sq_norms();
            for j in 0..p {
                if Some(j) == zero_col {
                    close(scales[j], 1.0, 1e-12)?;
                    close(norms[j], 0.0, 1e-12)?;
                } else {
                    close(norms[j], target * target, 1e-9)?;
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-4 tentpole property: the shared block-coordinate engine with the
/// trivial partition (all blocks of size 1) IS the scalar working-set
/// solver — coefficients and objective agree to 1e-12 on random Lasso and
/// MCP problems (MCP through the group-MCP block penalty).
#[test]
fn prop_block_engine_trivial_partition_matches_scalar() {
    use skglm::penalty::{GroupLasso, GroupMcp};
    use skglm::solver::{solve_blocks, BlockPartition};
    check(
        17,
        12,
        |rng: &mut Rng| {
            (
                20 + rng.below(30),          // n
                10 + rng.below(40),          // p
                0.05 + 0.3 * rng.uniform(),  // λ ratio
                rng.next_u64(),
            )
        },
        |&(n, p, ratio, seed)| {
            let ds = correlated(
                CorrelatedSpec { n, p, rho: 0.4, nnz: (p / 5).max(1), snr: 8.0 },
                seed,
            );
            let lam_max = skglm::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y);
            let lam = lam_max * ratio;
            // solve an order tighter than the 1e-12 comparison bar so the
            // two engines' optima gaps don't eat the whole tolerance
            let opts = SolverOpts::default().with_tol(1e-14);
            let part = BlockPartition::scalar(p);

            // --- Lasso ---
            let mut f = Quadratic::new();
            let scalar = solve(&ds.design, &ds.y, &mut f, &L1::new(lam), &opts, None, None);
            let mut gq = skglm::datafit::GroupedQuadratic::new(std::sync::Arc::new(
                BlockPartition::scalar(p),
            ));
            let block = solve_blocks(
                &ds.design, &ds.y, &part, &mut gq, &GroupLasso::new(lam), &opts, None,
            );
            close(scalar.objective, block.objective, 1e-12)?;
            for (j, (a, b)) in scalar.beta.iter().zip(block.v.iter()).enumerate() {
                ensure(
                    (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
                    format!("lasso beta[{j}]: scalar {a} vs block {b}"),
                )?;
            }

            // --- MCP (normalized design, the paper convention) ---
            let mut design = ds.design.clone();
            design.normalize_cols((n as f64).sqrt());
            let lam = skglm::estimators::linear::quadratic_lambda_max(&design, &ds.y) * ratio;
            let gamma = 3.0;
            let mut f2 = Quadratic::new();
            let scalar = solve(
                &design, &ds.y, &mut f2, &Mcp::new(lam, gamma), &opts, None, None,
            );
            let mut gq2 = skglm::datafit::GroupedQuadratic::new(std::sync::Arc::new(
                BlockPartition::scalar(p),
            ));
            let block = solve_blocks(
                &design, &ds.y, &part, &mut gq2, &GroupMcp::new(lam, gamma), &opts, None,
            );
            close(scalar.objective, block.objective, 1e-12)?;
            for (j, (a, b)) in scalar.beta.iter().zip(block.v.iter()).enumerate() {
                ensure(
                    (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
                    format!("mcp beta[{j}]: scalar {a} vs block {b}"),
                )?;
            }
            Ok(())
        },
    );
}

/// ISSUE-5 tentpole property: the Gram-domain inner engine IS the
/// residual engine — forced `InnerEngine::Gram` solves agree with forced
/// `InnerEngine::Residual` solves to 1e-12 on random Lasso AND (non-convex)
/// MCP problems, over dense AND sparse designs.
#[test]
fn prop_gram_inner_engine_matches_residual_engine() {
    use skglm::solver::InnerEngine;

    fn to_sparse(d: &Design) -> Design {
        match d {
            Design::Sparse(s) => Design::Sparse(s.clone()),
            Design::Dense(m) => {
                let mut trips = Vec::new();
                for j in 0..m.ncols() {
                    for (i, &v) in m.col(j).iter().enumerate() {
                        if v != 0.0 {
                            trips.push((i, j, v));
                        }
                    }
                }
                skglm::linalg::CscMatrix::from_triplets(m.nrows(), m.ncols(), &trips).into()
            }
        }
    }

    #[derive(Debug, Clone)]
    struct Probe {
        n: usize,
        p: usize,
        ratio: f64,
        sparse: bool,
        mcp: bool,
        seed: u64,
    }
    check(
        23,
        12,
        |rng: &mut Rng| Probe {
            n: 20 + rng.below(30),
            p: 10 + rng.below(40),
            ratio: 0.05 + 0.3 * rng.uniform(),
            sparse: rng.bernoulli(0.5),
            mcp: rng.bernoulli(0.5),
            seed: rng.next_u64(),
        },
        |pr| {
            let ds = correlated(
                CorrelatedSpec {
                    n: pr.n,
                    p: pr.p,
                    rho: 0.4,
                    nnz: (pr.p / 5).max(1),
                    snr: 8.0,
                },
                pr.seed,
            );
            let mut design =
                if pr.sparse { to_sparse(&ds.design) } else { ds.design.clone() };
            if pr.mcp {
                // paper convention for the non-convex penalty
                design.normalize_cols((pr.n as f64).sqrt());
            }
            let lam =
                skglm::estimators::linear::quadratic_lambda_max(&design, &ds.y) * pr.ratio;
            // solve an order tighter than the 1e-12 comparison bar
            let run = |inner: InnerEngine| {
                let opts = SolverOpts::default().with_tol(1e-14).with_inner(inner);
                let mut f = Quadratic::new();
                if pr.mcp {
                    solve(&design, &ds.y, &mut f, &Mcp::new(lam, 3.0), &opts, None, None)
                } else {
                    solve(&design, &ds.y, &mut f, &L1::new(lam), &opts, None, None)
                }
            };
            let residual = run(InnerEngine::Residual);
            let gram = run(InnerEngine::Gram);
            ensure(
                gram.profile.gram_epochs > 0 || gram.n_epochs == 0,
                "forced Gram run never used the Gram engine",
            )?;
            close(residual.objective, gram.objective, 1e-12)?;
            for (j, (a, b)) in residual.beta.iter().zip(gram.beta.iter()).enumerate() {
                ensure(
                    (a - b).abs() <= 1e-12 * (1.0 + a.abs()),
                    format!(
                        "{}{} beta[{j}]: residual {a} vs gram {b}",
                        if pr.sparse { "sparse " } else { "dense " },
                        if pr.mcp { "mcp" } else { "lasso" }
                    ),
                )?;
            }
            Ok(())
        },
    );
}

/// Group prox with the trivial partition equals the scalar prox for every
/// (penalty, v, step) probe — the pointwise half of the equivalence.
#[test]
fn prop_group_prox_trivial_partition_equals_scalar_prox() {
    use skglm::penalty::{BlockPenalty, GroupLasso, GroupMcp, GroupScad, WeightedGroupLasso};
    check(
        19,
        CASES,
        |rng: &mut Rng| {
            (
                rng.uniform_range(-6.0, 6.0),
                rng.uniform_range(0.05, 1.5),
                rng.uniform_range(0.01, 2.0),
                rng.uniform_range(4.0 /* > 1 + max step: SCAD regime */, 8.0),
            )
        },
        |&(v, step, lam, gamma)| {
            let mut b = [v];
            GroupLasso::new(lam).prox(&mut b, step, 0);
            close(b[0], soft_threshold(v, step * lam), 1e-13)?;
            let mut b = [v];
            WeightedGroupLasso::new(lam, vec![1.0]).prox(&mut b, step, 0);
            close(b[0], soft_threshold(v, step * lam), 1e-13)?;
            let mut b = [v];
            GroupMcp::new(lam, gamma).prox(&mut b, step, 0);
            close(b[0], Mcp::new(lam, gamma).prox(v, step, 0), 1e-13)?;
            let mut b = [v];
            GroupScad::new(lam, gamma).prox(&mut b, step, 0);
            close(b[0], Scad::new(lam, gamma).prox(v, step, 0), 1e-13)?;
            Ok(())
        },
    );
}

/// Subgradient inclusion: `x = prox_{step·g}(v)` is optimal for
/// `½(x−v)² + step·g(x)`, so `(v−x)/step ∈ ∂g(x)` — equivalently the
/// penalty's own score of the point must vanish:
/// `subdiff_distance(x, (x−v)/step) ≈ 0` (it measures
/// `dist(−grad, ∂g(x))`, and here `−grad = (v−x)/step`). This ties every
/// scalar penalty's closed-form prox to its hand-derived subdifferential
/// — a sign error in either one breaks the identity. ℓ_q is checked only
/// away from 0 (`subdiff_distance` is defined as 0 there; the solver
/// scores ℓ_q by the fixed-point violation instead, see
/// `Penalty::use_cd_score`).
#[test]
fn prop_prox_satisfies_subgradient_inclusion_all_penalties() {
    use skglm::penalty::WeightedL1;

    #[derive(Debug, Clone)]
    struct Probe {
        v: f64,
        step: f64,
        lam: f64,
        /// margins above each penalty's validity floor (MCP: γ > step;
        /// SCAD: γ > 1 + step)
        gamma_margin: f64,
        q: f64,
        weight: f64,
    }
    check(
        29,
        CASES,
        |rng: &mut Rng| Probe {
            v: rng.uniform_range(-10.0, 10.0),
            step: rng.uniform_range(0.01, 2.0),
            lam: rng.uniform_range(0.0, 2.0),
            gamma_margin: rng.uniform_range(0.5, 3.5),
            q: rng.uniform_range(0.3, 0.9),
            // exercise w = 0 (unpenalized feature) on ~1/5 of cases
            weight: if rng.bernoulli(0.2) { 0.0 } else { rng.uniform_range(0.1, 3.0) },
        },
        |pr| {
            // the score is a distance in gradient units ≈ λ/step scale;
            // closed forms are exact, so only rounding headroom is needed
            let tol = 1e-8 * (1.0 + pr.lam) * (1.0 + 1.0 / pr.step);
            let run = |name: &str, prox: &dyn Fn(f64, f64) -> f64, score: &dyn Fn(f64, f64) -> f64, skip_at_zero: bool| {
                let x = prox(pr.v, pr.step);
                if skip_at_zero && x == 0.0 {
                    return Ok(());
                }
                let grad = (x - pr.v) / pr.step; // so −grad = (v−x)/step
                let d = score(x, grad);
                ensure(
                    d <= tol,
                    format!(
                        "{name}: prox({}, {}) = {x} violates subgradient inclusion: dist {d:.3e} > {tol:.3e}",
                        pr.v, pr.step
                    ),
                )
            };

            let p = L1::new(pr.lam);
            run("l1", &|v, s| p.prox(v, s, 0), &|x, g| p.subdiff_distance(x, g, 0), false)?;

            let p = WeightedL1::new(pr.lam, vec![pr.weight]);
            run("weighted_l1", &|v, s| p.prox(v, s, 0), &|x, g| p.subdiff_distance(x, g, 0), false)?;

            let p = L1L2::new(pr.lam, 0.5);
            run("enet", &|v, s| p.prox(v, s, 0), &|x, g| p.subdiff_distance(x, g, 0), false)?;

            let p = Mcp::new(pr.lam, pr.step + pr.gamma_margin);
            run("mcp", &|v, s| p.prox(v, s, 0), &|x, g| p.subdiff_distance(x, g, 0), false)?;

            // SCAD needs both the constructor floor (γ > 2) and the
            // prox-regime floor (γ > 1 + step)
            let p = Scad::new(pr.lam, 2.0_f64.max(1.0 + pr.step) + pr.gamma_margin);
            run("scad", &|v, s| p.prox(v, s, 0), &|x, g| p.subdiff_distance(x, g, 0), false)?;

            let p = Lq::new(pr.lam, pr.q);
            run("lq", &|v, s| p.prox(v, s, 0), &|x, g| p.subdiff_distance(x, g, 0), true)?;

            Ok(())
        },
    );
}

/// Batched multi-RHS solves match their scalar runs (ISSUE 9): for a
/// random design seen both dense and CSC, mixed L1/MCP members, and
/// batch widths B ∈ {1, 2, 8, 33}, every member of one `solve_batch`
/// call agrees with its own scalar solve to 1e-12 on the coefficients
/// and the objective (the engines are in fact bit-identical — 1e-12 is
/// the ISSUE's acceptance bar).
#[test]
fn prop_batch_members_match_scalar_solver() {
    use skglm::penalty::BatchPenalty;
    use skglm::solver::{solve_batch, BatchFit};

    check(
        9,
        4,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::seed_from_u64(seed);
            let (n, p) = (50, 70);
            let mut rows = Vec::new();
            let mut trips = Vec::new();
            for i in 0..n {
                let mut row = vec![0.0; p];
                for j in 0..p {
                    if rng.bernoulli(0.3) {
                        let v = rng.normal();
                        row[j] = v;
                        trips.push((i, j, v));
                    }
                }
                rows.push(row);
            }
            let dense: Design = skglm::linalg::DenseMatrix::from_rows(&rows).into();
            let sparse: Design = skglm::linalg::CscMatrix::from_triplets(n, p, &trips).into();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let opts = SolverOpts::default().with_tol(1e-10);

            for design in [&dense, &sparse] {
                let lam_max = skglm::estimators::linear::quadratic_lambda_max(design, &y);
                // γ safely above 1/L_min so MCP members are valid for
                // every step size the CD loop can take on this design
                let min_l = design
                    .col_sq_norms()
                    .iter()
                    .map(|&s| s / n as f64)
                    .filter(|&l| l > 0.0)
                    .fold(f64::INFINITY, f64::min);
                let gamma = (2.0 / min_l).max(3.0);

                for &b in &[1usize, 2, 8, 33] {
                    // member k: λ geometric in k, alternating L1 / MCP
                    let lams: Vec<f64> = (0..b)
                        .map(|k| {
                            let t = if b == 1 { 0.0 } else { k as f64 / (b - 1) as f64 };
                            lam_max * 0.5 * (0.1f64).powf(t)
                        })
                        .collect();
                    let fits: Vec<BatchFit> = lams
                        .iter()
                        .enumerate()
                        .map(|(k, &lam)| {
                            let pen = if k % 2 == 0 {
                                BatchPenalty::L1(L1::new(lam))
                            } else {
                                BatchPenalty::Mcp(Mcp::new(lam, gamma))
                            };
                            BatchFit::new(pen)
                        })
                        .collect();
                    let out = solve_batch(design, &y, fits, &opts, None, None);
                    ensure(
                        out.members.len() == b,
                        format!("B={b}: got {} members", out.members.len()),
                    )?;
                    for (k, &lam) in lams.iter().enumerate() {
                        let mut f = Quadratic::new();
                        let scalar = if k % 2 == 0 {
                            solve(design, &y, &mut f, &L1::new(lam), &opts, None, None)
                        } else {
                            solve(design, &y, &mut f, &Mcp::new(lam, gamma), &opts, None, None)
                        };
                        let m = &out.members[k].result;
                        close(m.objective, scalar.objective, 1e-12)?;
                        for (x, z) in m.beta.iter().zip(scalar.beta.iter()) {
                            ensure(
                                (x - z).abs() <= 1e-12,
                                format!(
                                    "B={b} member {k}: beta {x} vs scalar {z} (diff {:.3e})",
                                    (x - z).abs()
                                ),
                            )?;
                        }
                        ensure(
                            out.members[k].stopped.is_none(),
                            format!("B={b} member {k}: unexpected early stop"),
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-10 tentpole property, part 1: every vector ISA this host
/// supports reproduces the scalar kernels. The non-FMA variants are
/// bit-exact against the dispatched scalar `dot` lane order (every
/// panel output *is* that dot); the FMA variants agree to ≤ 1e-12.
#[test]
fn prop_simd_kernels_match_scalar() {
    use skglm::linalg::{simd, DenseMatrix, KernelIsa};

    const VECTOR_ISAS: [KernelIsa; 4] =
        [KernelIsa::Avx2, KernelIsa::Avx2Fma, KernelIsa::Neon, KernelIsa::NeonFma];

    check(
        17,
        30,
        |rng: &mut Rng| (rng.below(90), 1 + rng.below(40), 1 + rng.below(5), rng.next_u64()),
        |&(n, p, n_rhs, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let data: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
            let m = DenseMatrix::from_col_major(n, p, data);
            let r: Vec<f64> = (0..n * n_rhs).map(|_| rng.normal()).collect();
            let gather_cols: Vec<usize> = (0..p.min(11)).map(|_| rng.below(p)).collect();
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let alpha = rng.uniform_range(-2.0, 2.0);

            // the scalar-dot references every vector output must hit
            let dot_ref: Vec<f64> =
                (0..p).map(|j| simd::dot_with(KernelIsa::Scalar, m.col(j), &r[..n])).collect();
            let mm_ref: Vec<f64> = (0..p)
                .flat_map(|j| {
                    (0..n_rhs)
                        .map(|c| {
                            simd::dot_with(KernelIsa::Scalar, m.col(j), &r[c * n..(c + 1) * n])
                        })
                        .collect::<Vec<f64>>()
                })
                .collect();
            let mut axpy_ref = x.clone();
            simd::axpy_with(KernelIsa::Scalar, alpha, &r[..n], &mut axpy_ref);

            for which in VECTOR_ISAS {
                if !which.supported() {
                    continue;
                }
                let cmp = |got: f64, want: f64, what: &str| {
                    if which.is_fma() {
                        close(got, want, 1e-12)
                            .map_err(|e| format!("{}/{what}: {e}", which.as_str()))
                    } else {
                        ensure(
                            got.to_bits() == want.to_bits(),
                            format!("{}/{what}: {got} != {want} bitwise", which.as_str()),
                        )
                    }
                };

                let mut out = vec![0.0; p];
                simd::matvec_t_panel_with(which, &m, &r[..n], 0..p, &mut out);
                for j in 0..p {
                    cmp(out[j], dot_ref[j], "matvec_t_panel")?;
                }

                let mut out = vec![0.0; p * n_rhs];
                simd::matmul_t_panel_with(which, &m, &r, n_rhs, 0..p, &mut out);
                for (k, &want) in mm_ref.iter().enumerate() {
                    cmp(out[k], want, "matmul_t_panel")?;
                }

                let mut out = vec![0.0; gather_cols.len()];
                simd::gather_dots_panel_with(which, &m, &r[..n], &gather_cols, &mut out);
                for (k, &j) in gather_cols.iter().enumerate() {
                    cmp(out[k], dot_ref[j], "gather_dots_panel")?;
                }

                if p > 0 {
                    cmp(
                        simd::dot_with(which, m.col(0), &r[..n]),
                        dot_ref[0],
                        "dot",
                    )?;
                }
                let mut y = x.clone();
                simd::axpy_with(which, alpha, &r[..n], &mut y);
                for i in 0..n {
                    cmp(y[i], axpy_ref[i], "axpy")?;
                }
            }
            Ok(())
        },
    );
}

/// ISSUE-10 tentpole property, part 2: the reduced-precision dots have
/// no FMA variants, so every supported ISA must reproduce the scalar
/// references bit-for-bit — and both modes track the f64 dot within
/// f32 rounding of the summed products.
#[test]
fn prop_reduced_dots_are_isa_invariant_and_accurate() {
    use skglm::linalg::{simd, KernelIsa, Precision};

    const ISAS: [KernelIsa; 5] = [
        KernelIsa::Scalar,
        KernelIsa::Avx2,
        KernelIsa::Avx2Fma,
        KernelIsa::Neon,
        KernelIsa::NeonFma,
    ];

    check(
        19,
        40,
        |rng: &mut Rng| (rng.below(200), rng.next_u64()),
        |&(n, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let a64: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b64: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let a32: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = b64.iter().map(|&v| v as f32).collect();

            let mixed_ref = simd::dot_mixed_scalar(&a32, &b32);
            let f32_ref = simd::dot_f32_scalar(&a32, &b32);
            for which in ISAS {
                if !which.supported() {
                    continue;
                }
                let got = simd::dot_mixed_with(which, &a32, &b32);
                ensure(
                    got.to_bits() == mixed_ref.to_bits(),
                    format!("mixed dot differs on {}: {got} vs {mixed_ref}", which.as_str()),
                )?;
                let got = simd::dot_f32_with(which, &a32, &b32);
                ensure(
                    got.to_bits() == f32_ref.to_bits(),
                    format!("f32 dot differs on {}: {got} vs {f32_ref}", which.as_str()),
                )?;
            }

            // accuracy vs the f64 dot: error bounded by f32 rounding of
            // the accumulated |a_i b_i| mass
            let exact: f64 = a64.iter().zip(&b64).map(|(x, z)| x * z).sum();
            let mass: f64 = a64.iter().zip(&b64).map(|(x, z)| (x * z).abs()).sum();
            let bound = 1e-5 * (1.0 + mass);
            for (prec, got) in
                [(Precision::Mixed, mixed_ref), (Precision::F32, f32_ref)]
            {
                ensure(
                    (got - exact).abs() <= bound,
                    format!(
                        "{} dot drifted: |{got} - {exact}| > {bound}",
                        prec.as_str()
                    ),
                )?;
                // reduced_dot is the same kernel behind the Precision enum
                let via_enum = simd::reduced_dot(prec, &a32, &b32);
                ensure(
                    via_enum.to_bits() == got.to_bits(),
                    format!("reduced_dot({}) disagrees with the *_with kernel", prec.as_str()),
                )?;
            }
            Ok(())
        },
    );
}

/// ISSUE-10 tentpole property, part 3: reduced-precision solves still
/// converge, their f64 KKT certificate lands under the floored
/// tolerance, the solution stays close to the f64 fit, and the profile
/// is labeled with the mode that produced it.
#[test]
fn prop_reduced_precision_solves_meet_floored_certificate() {
    use skglm::linalg::{simd, Precision};

    check(
        23,
        4,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let ds = correlated(
                CorrelatedSpec { n: 60, p: 90, rho: 0.4, nnz: 8, snr: 8.0 },
                seed,
            );
            let lam_max =
                skglm::estimators::linear::quadratic_lambda_max(&ds.design, &ds.y);
            let lam = 0.1 * lam_max;

            for prec in [Precision::Mixed, Precision::F32] {
                let opts = SolverOpts::default().with_tol(1e-8).with_precision(prec);
                let bar = opts.tol.max(prec.tol_floor());
                let f64_opts = SolverOpts::default().with_tol(1e-8);

                for (name, is_l1) in [("l1", true), ("mcp", false)] {
                    let run = |o: &SolverOpts| {
                        let mut f = Quadratic::new();
                        if is_l1 {
                            solve(&ds.design, &ds.y, &mut f, &L1::new(lam), o, None, None)
                        } else {
                            solve(&ds.design, &ds.y, &mut f, &Mcp::new(lam, 3.0), o, None, None)
                        }
                    };
                    let res = run(&opts);
                    let gold = run(&f64_opts);
                    ensure(
                        res.converged,
                        format!("{}/{name}: did not converge", prec.as_str()),
                    )?;
                    ensure(
                        res.kkt <= bar * 1.000001,
                        format!(
                            "{}/{name}: kkt {} above floored tol {bar}",
                            prec.as_str(),
                            res.kkt
                        ),
                    )?;
                    close(res.objective, gold.objective, 1e-2)
                        .map_err(|e| format!("{}/{name} objective: {e}", prec.as_str()))?;
                    ensure(
                        res.profile.precision == prec,
                        format!("{}/{name}: profile precision unlabeled", prec.as_str()),
                    )?;
                    ensure(
                        res.profile.kernel_isa == simd::isa(),
                        format!("{}/{name}: profile isa unlabeled", prec.as_str()),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// PR 2's thread bit-invariance contract, re-pinned per ISA: however the
/// active ISA splits the panel across threads, every output bit matches
/// the single-thread pass (asserted via `to_bits`, not a tolerance).
#[test]
fn prop_thread_split_is_bit_invariant_under_active_isa() {
    use skglm::linalg::simd;

    check(
        29,
        25,
        |rng: &mut Rng| (1 + rng.below(150), 1 + rng.below(70), rng.next_u64()),
        |&(n, p, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let data: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
            let design: Design = skglm::linalg::DenseMatrix::from_col_major(n, p, data).into();
            let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let mut base = vec![0.0; p];
            design.matvec_t_threads(&r, &mut base, 1);
            for threads in [2usize, 3, 5, 8] {
                let mut out = vec![0.0; p];
                design.matvec_t_threads(&r, &mut out, threads);
                for j in 0..p {
                    ensure(
                        out[j].to_bits() == base[j].to_bits(),
                        format!(
                            "isa {}: {threads}-thread split changed bits at col {j}",
                            simd::isa().as_str()
                        ),
                    )?;
                }
            }
            Ok(())
        },
    );
}
