//! Integration: the path-aware fit scheduler — completion-order
//! streaming, warm-start continuity along λ paths (with gap-safe
//! screening active), cache sharing across jobs, and clean shutdown with
//! jobs in flight.

use skglm::coordinator::{specs, FitScheduler, Job, JobEvent};
use skglm::data::{correlated, CorrelatedSpec, Dataset};
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::estimators::path::geometric_grid;
use skglm::solver::SolverOpts;
use std::sync::Arc;

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(correlated(CorrelatedSpec { n: 80, p: 120, rho: 0.5, nnz: 8, snr: 10.0 }, seed))
}

#[test]
fn path_job_streams_every_point_then_done() {
    let ds = dataset(11);
    let ratios = geometric_grid(1e-2, 7);
    let sched = FitScheduler::start(1);
    let job = sched.submit_path(
        Arc::clone(&ds),
        specs::lasso(1.0),
        ratios.clone(),
        SolverOpts::default().with_tol(1e-8),
    );
    let events = sched.collect_events(ratios.len() + 1);
    sched.shutdown();

    let mut seen_indices = Vec::new();
    let mut done = false;
    for (k, e) in events.iter().enumerate() {
        assert_eq!(e.job_id(), job, "every event tagged with the path job id");
        match e {
            JobEvent::PathPoint(p) => {
                assert!(!done, "no points after PathDone");
                seen_indices.push(p.index);
                assert!(p.point.lambda_ratio <= 1.0 + 1e-12);
            }
            JobEvent::PathDone(s) => {
                assert_eq!(k, events.len() - 1, "PathDone is the terminal event");
                assert_eq!(s.n_points, ratios.len());
                done = true;
            }
            JobEvent::FitDone(_) => panic!("unexpected single-fit event"),
            JobEvent::Failed { job_id, message } => {
                panic!("path job {job_id} failed: {message}")
            }
            other => panic!("unexpected terminal event for job {}", other.job_id()),
        }
    }
    assert!(done);
    // points stream in sweep order (one worker, descending λ)
    assert_eq!(seen_indices, (0..ratios.len()).collect::<Vec<_>>());
}

#[test]
fn warm_path_matches_cold_fits_and_costs_fewer_epochs() {
    // Warm-start continuity: at every λᵢ₊₁ the warm-started (and
    // gap-safe-screened) solution must reach the same optimum as a cold
    // fit — never worse — while spending fewer CD epochs overall.
    let ds = dataset(12);
    let ratios = geometric_grid(5e-3, 9);
    let tol = 1e-9;
    let sched = FitScheduler::start(1);
    sched.submit_path(
        Arc::clone(&ds),
        specs::lasso(1.0),
        ratios.clone(),
        SolverOpts::default().with_tol(tol),
    );
    let events = sched.collect_events(ratios.len() + 1);
    sched.shutdown();

    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let mut warm_epochs = 0;
    let mut cold_epochs = 0;
    let mut screened_total = 0;
    for e in &events {
        if let JobEvent::PathPoint(p) = e {
            let cold = skglm::estimators::Lasso::new(p.point.lambda)
                .with_tol(tol)
                .fit(&ds.design, &ds.y);
            assert!(
                p.point.objective <= cold.objective + 1e-8,
                "warm objective {} worse than cold {} at ratio {}",
                p.point.objective,
                cold.objective,
                p.point.lambda_ratio
            );
            assert!((p.point.lambda - lam_max * p.point.lambda_ratio).abs() < 1e-12);
            warm_epochs += p.epochs;
            cold_epochs += cold.n_epochs;
            screened_total += p.n_screened;
        }
    }
    assert!(
        warm_epochs < cold_epochs,
        "warm path ({warm_epochs} epochs) should beat cold fits ({cold_epochs} epochs)"
    );
    assert!(screened_total > 0, "gap-safe screening should certify features on a lasso path");
}

#[test]
fn nonconvex_path_converges_at_every_point() {
    let ds = dataset(13);
    let ratios = geometric_grid(5e-2, 6);
    let sched = FitScheduler::start(1);
    sched.submit_path(
        Arc::clone(&ds),
        specs::mcp(1.0, 3.0),
        ratios.clone(),
        SolverOpts::default().with_tol(1e-7),
    );
    let events = sched.collect_events(ratios.len() + 1);
    sched.shutdown();
    let points: Vec<_> = events
        .iter()
        .filter_map(|e| match e {
            JobEvent::PathPoint(p) => Some(p),
            _ => None,
        })
        .collect();
    assert_eq!(points.len(), ratios.len());
    // support grows (weakly) as λ decreases on the normalized design
    assert!(points.last().unwrap().point.support_size >= points[0].point.support_size);
}

#[test]
fn mixed_fit_and_path_jobs_interleave_with_correct_tags() {
    let ds = dataset(14);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let ratios = geometric_grid(1e-2, 5);
    let sched = FitScheduler::start(3);
    let path_id = sched.submit_path(
        Arc::clone(&ds),
        specs::lasso(1.0),
        ratios.clone(),
        SolverOpts::default().with_tol(1e-8),
    );
    let fit_ids: Vec<u64> = (1..=4)
        .map(|k| {
            sched.submit_fit(
                Arc::clone(&ds),
                specs::elastic_net(lam_max / (5.0 * k as f64), 0.7),
                SolverOpts::default(),
            )
        })
        .collect();
    let events = sched.collect_events(ratios.len() + 1 + fit_ids.len());
    sched.shutdown();

    let mut fit_seen = 0;
    let mut path_points = 0;
    let mut path_done = 0;
    for e in &events {
        match e {
            JobEvent::FitDone(o) => {
                assert!(fit_ids.contains(&o.job_id));
                assert_eq!(o.label, "quadratic/l1l2");
                fit_seen += 1;
            }
            JobEvent::PathPoint(p) => {
                assert_eq!(p.job_id, path_id);
                path_points += 1;
            }
            JobEvent::PathDone(s) => {
                assert_eq!(s.job_id, path_id);
                path_done += 1;
            }
            JobEvent::Failed { job_id, message } => {
                panic!("job {job_id} failed: {message}")
            }
            other => panic!("unexpected terminal event for job {}", other.job_id()),
        }
    }
    assert_eq!(fit_seen, fit_ids.len());
    assert_eq!(path_points, ratios.len());
    assert_eq!(path_done, 1);
}

#[test]
fn shutdown_with_jobs_in_flight_does_not_hang_or_panic() {
    let ds = dataset(15);
    let lam_max = quadratic_lambda_max(&ds.design, &ds.y);
    let sched = FitScheduler::start(2);
    for k in 1..=6 {
        sched.submit_fit(
            Arc::clone(&ds),
            specs::lasso(lam_max / (3.0 * k as f64)),
            SolverOpts::default(),
        );
    }
    sched.submit_path(
        Arc::clone(&ds),
        specs::lasso(1.0),
        geometric_grid(1e-2, 6),
        SolverOpts::default(),
    );
    // never read a single event: workers must drain the queue and exit,
    // ignoring sends into the dropped receiver
    sched.shutdown();
}

#[test]
fn generic_job_enum_roundtrip() {
    // the open Job enum is part of the public API (custom schedulers);
    // logistic needs ±1 labels, so binarize the synthetic targets
    let raw = dataset(16);
    let labels: Vec<f64> = raw.y.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
    let ds = Arc::new(Dataset {
        name: "logit".to_string(),
        design: raw.design.clone(),
        y: labels,
        beta_true: Vec::new(),
    });
    let lam = skglm::estimators::SparseLogisticRegression::lambda_max(&ds.design, &ds.y) / 6.0;
    let sched = FitScheduler::start(1);
    let id = sched.submit(Job::Fit {
        dataset: Arc::clone(&ds),
        spec: specs::logistic_l1(lam),
        opts: SolverOpts::default().with_tol(1e-6),
    });
    let events = sched.collect_events(1);
    sched.shutdown();
    match &events[0] {
        JobEvent::FitDone(o) => {
            assert_eq!(o.job_id, id);
            assert_eq!(o.label, "logistic/l1");
        }
        _ => panic!("expected a fit event"),
    }
}
