//! Kernel-engine integration: thread-count invariance of full solves, the
//! scheduler/kernel thread-budget sharing rule, and end-to-end agreement
//! of the routed O(n·p) passes with their serial references.
//!
//! Budget-mutating checks live in ONE test function: the budget is a
//! process-global and `cargo test` runs test functions concurrently.

use skglm::coordinator::{specs, FitScheduler, JobEvent};
use skglm::data::{correlated, CorrelatedSpec};
use skglm::datafit::Quadratic;
use skglm::estimators::linear::quadratic_lambda_max;
use skglm::linalg::parallel::{self, KernelPolicy};
use skglm::penalty::{Mcp, L1};
use skglm::solver::{solve, SolverOpts};
use std::sync::Arc;

/// Problem big enough (n·p = 120 000 stored entries) that the policy
/// engages the parallel path at thread budgets > 1.
fn big_problem() -> skglm::data::Dataset {
    correlated(CorrelatedSpec { n: 300, p: 400, rho: 0.5, nnz: 20, snr: 8.0 }, 11)
}

#[test]
fn budget_rules_and_thread_invariance() {
    let saved = parallel::thread_budget();

    // --- oversubscription rule: kernel threads × workers ≤ budget ---
    parallel::set_thread_budget(8);
    {
        let sched = FitScheduler::start(4);
        assert_eq!(
            KernelPolicy::global().threads,
            2,
            "4 workers on a budget of 8 must leave 2 kernel threads each"
        );
        {
            // a second scheduler stacks: 4 + 2 workers > budget → 1 thread
            let sched2 = FitScheduler::start(2);
            assert_eq!(KernelPolicy::global().threads, 1);
            sched2.shutdown();
        }
        sched.shutdown();
    }
    assert_eq!(
        KernelPolicy::global().threads,
        8,
        "shutdown must release the workers' budget share"
    );

    // --- full solves are invariant to the thread budget ---
    let ds = big_problem();
    let lam = quadratic_lambda_max(&ds.design, &ds.y) / 10.0;
    let run_lasso = |budget: usize| {
        parallel::set_thread_budget(budget);
        let mut f = Quadratic::new();
        solve(
            &ds.design,
            &ds.y,
            &mut f,
            &L1::new(lam),
            &SolverOpts::default().with_tol(1e-10),
            None,
            None,
        )
    };
    let serial = run_lasso(1);
    let parallel_fit = run_lasso(4);
    assert!(serial.converged && parallel_fit.converged);
    assert!(
        (serial.objective - parallel_fit.objective).abs() < 1e-12,
        "objectives diverged: {} vs {}",
        serial.objective,
        parallel_fit.objective
    );
    for (a, b) in serial.beta.iter().zip(parallel_fit.beta.iter()) {
        assert!((a - b).abs() < 1e-12, "beta diverged: {a} vs {b}");
    }

    // same for a non-convex penalty on a normalised design
    let run_mcp = |budget: usize| {
        parallel::set_thread_budget(budget);
        let mut design = ds.design.clone();
        design.normalize_cols((ds.n() as f64).sqrt());
        let lam = quadratic_lambda_max(&design, &ds.y) / 10.0;
        let mut f = Quadratic::new();
        solve(
            &design,
            &ds.y,
            &mut f,
            &Mcp::new(lam, 3.0),
            &SolverOpts::default().with_tol(1e-9),
            None,
            None,
        )
    };
    let mcp_serial = run_mcp(1);
    let mcp_parallel = run_mcp(4);
    assert!(
        (mcp_serial.objective - mcp_parallel.objective).abs() < 1e-12,
        "MCP objectives diverged: {} vs {}",
        mcp_serial.objective,
        mcp_parallel.objective
    );
    for (a, b) in mcp_serial.beta.iter().zip(mcp_parallel.beta.iter()) {
        assert!((a - b).abs() < 1e-12);
    }

    // --- scheduler path job under a multi-thread budget matches the
    //     single-threaded reference sweep ---
    parallel::set_thread_budget(4);
    let shared = Arc::new(big_problem());
    let ratios = vec![0.5, 0.2, 0.08];
    let opts = SolverOpts::default().with_tol(1e-9);
    let sched = FitScheduler::start(2);
    sched.submit_path(Arc::clone(&shared), specs::lasso(1.0), ratios.clone(), opts.clone());
    let mut par_points: Vec<(usize, f64, usize)> = Vec::new();
    loop {
        match sched.events.recv().expect("scheduler died") {
            JobEvent::PathPoint(p) => {
                par_points.push((p.index, p.point.objective, p.point.support_size));
            }
            JobEvent::PathDone(_) => break,
            JobEvent::FitDone(_) => {}
            JobEvent::Failed { job_id, message } => {
                panic!("path job {job_id} failed: {message}")
            }
            other => panic!("unexpected terminal event for job {}", other.job_id()),
        }
    }
    sched.shutdown();

    parallel::set_thread_budget(1);
    let sched = FitScheduler::start(1);
    sched.submit_path(Arc::clone(&shared), specs::lasso(1.0), ratios, opts);
    let mut ser_points: Vec<(usize, f64, usize)> = Vec::new();
    loop {
        match sched.events.recv().expect("scheduler died") {
            JobEvent::PathPoint(p) => {
                ser_points.push((p.index, p.point.objective, p.point.support_size));
            }
            JobEvent::PathDone(_) => break,
            JobEvent::FitDone(_) => {}
            JobEvent::Failed { job_id, message } => {
                panic!("path job {job_id} failed: {message}")
            }
            other => panic!("unexpected terminal event for job {}", other.job_id()),
        }
    }
    sched.shutdown();

    par_points.sort_by_key(|x| x.0);
    ser_points.sort_by_key(|x| x.0);
    assert_eq!(par_points.len(), ser_points.len());
    for (a, b) in par_points.iter().zip(ser_points.iter()) {
        assert_eq!(a.2, b.2, "support sizes diverged at path index {}", a.0);
        assert!(
            (a.1 - b.1).abs() < 1e-12,
            "path objectives diverged at index {}: {} vs {}",
            a.0,
            a.1,
            b.1
        );
    }

    parallel::set_thread_budget(saved);
}

#[test]
fn routed_passes_match_serial_references_end_to_end() {
    // exercised with explicit thread counts — no global state touched
    let ds = big_problem();
    let d = &ds.design;
    let r: Vec<f64> = (0..ds.n()).map(|i| (i as f64 * 0.31).sin()).collect();

    let mut reference = vec![0.0; ds.p()];
    match d {
        skglm::linalg::Design::Dense(m) => m.matvec_t(&r, &mut reference),
        skglm::linalg::Design::Sparse(m) => m.matvec_t(&r, &mut reference),
    }
    for threads in [1usize, 2, 3, 8] {
        let mut out = vec![0.0; ds.p()];
        d.matvec_t_threads(&r, &mut out, threads);
        for j in 0..ds.p() {
            assert!(
                (out[j] - reference[j]).abs() < 1e-12,
                "threads={threads} j={j}: {} vs {}",
                out[j],
                reference[j]
            );
        }
        let mut norms = vec![0.0; ds.p()];
        d.col_sq_norms_threads(&mut norms, threads);
        let serial_norms = match d {
            skglm::linalg::Design::Dense(m) => m.col_sq_norms(),
            skglm::linalg::Design::Sparse(m) => m.col_sq_norms(),
        };
        for j in 0..ds.p() {
            assert!((norms[j] - serial_norms[j]).abs() < 1e-12);
        }
    }
}
