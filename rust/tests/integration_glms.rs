//! End-to-end tests for the prox-Newton GLM subsystem (ISSUE 3):
//! Poisson/probit path jobs through the FitScheduler, prox-Newton vs
//! OWL-QN (L-BFGS) objective agreement at the ≤1e-6 relative bar, and
//! the CV λ-grid leakage regression.

use skglm::coordinator::{specs, FitScheduler, JobEvent};
use skglm::data::{correlated, poisson_correlated, probit_correlated, CorrelatedSpec};
use skglm::datafit::{Poisson, Probit};
use skglm::estimators::path::geometric_grid;
use skglm::penalty::L1;
use skglm::solver::baselines::owlqn::solve_owlqn;
use skglm::solver::{glm_lambda_max, solve_prox_newton, SolverOpts};
use std::sync::Arc;

#[test]
fn poisson_path_streams_through_the_scheduler() {
    // the `skglm path --datafit poisson` code path: a warm-started λ
    // sweep of an ℓ1-Poisson spec on a scheduler worker
    let ds = Arc::new(poisson_correlated(
        CorrelatedSpec { n: 120, p: 150, rho: 0.4, nnz: 6, snr: 0.0 },
        42,
    ));
    let n_points = 6;
    let ratios = geometric_grid(1e-2, n_points);
    let sched = FitScheduler::start(1);
    let job = sched.submit_path(
        Arc::clone(&ds),
        specs::poisson_l1(1.0),
        ratios,
        SolverOpts::default().with_tol(1e-7),
    );
    let mut points = Vec::new();
    let mut done = false;
    while !done {
        match sched.events.recv().expect("scheduler died") {
            JobEvent::PathPoint(p) => {
                assert_eq!(p.job_id, job);
                points.push(p);
            }
            JobEvent::PathDone(s) => {
                assert_eq!(s.n_points, n_points);
                done = true;
            }
            JobEvent::FitDone(_) => panic!("unexpected fit event"),
            JobEvent::Failed { job_id, message } => {
                panic!("path job {job_id} failed: {message}")
            }
            JobEvent::Cancelled { job_id, .. } => panic!("job {job_id} cancelled"),
            JobEvent::SchedulerDown => panic!("scheduler died"),
        }
    }
    sched.shutdown();
    assert_eq!(points.len(), n_points);
    // grid is swept high→low λ: support grows along the sweep
    points.sort_by_key(|p| p.index);
    assert!(
        points.last().unwrap().point.support_size >= points[0].point.support_size,
        "support should grow as λ shrinks"
    );
    assert!(points.iter().all(|p| p.point.objective.is_finite()));
    // the synthetic problem has ground truth: metrics must be populated
    assert!(points.iter().all(|p| p.point.estimation_error.is_some()));
}

#[test]
fn probit_fit_and_path_specs_run_through_the_scheduler() {
    let ds = Arc::new(probit_correlated(
        CorrelatedSpec { n: 100, p: 80, rho: 0.4, nnz: 5, snr: 0.0 },
        7,
    ));
    let lam_max = specs::probit_l1(1.0).lambda_max(&ds.design, &ds.y);
    let sched = FitScheduler::start(2);
    sched.submit_fit(Arc::clone(&ds), specs::probit_l1(lam_max / 8.0), SolverOpts::default());
    sched.submit_fit(Arc::clone(&ds), specs::probit_l1(lam_max / 15.0), SolverOpts::default());
    let outcomes = sched.collect_fits(2);
    sched.shutdown();
    for o in &outcomes {
        assert!(o.result.converged, "{}: kkt = {}", o.label, o.result.kkt);
        assert_eq!(o.label, "probit/l1");
    }
}

#[test]
fn prox_newton_matches_lbfgs_objective_on_l1_poisson() {
    // the ISSUE 3 acceptance bar: ≤ 1e-6 relative objective agreement
    // between prox-Newton and the OWL-QN (orthant-wise L-BFGS) baseline
    let ds = poisson_correlated(
        CorrelatedSpec { n: 200, p: 100, rho: 0.4, nnz: 8, snr: 0.0 },
        11,
    );
    let lam = glm_lambda_max(&Poisson::new(), &ds.design, &ds.y) / 10.0;
    let mut f1 = Poisson::new();
    let pn = solve_prox_newton(
        &ds.design,
        &ds.y,
        &mut f1,
        &L1::new(lam),
        &SolverOpts::default().with_tol(1e-10),
        None,
    );
    assert!(pn.converged, "prox-Newton kkt = {}", pn.kkt);
    let mut f2 = Poisson::new();
    let owl = solve_owlqn(&ds.design, &ds.y, &mut f2, lam, 10, 10_000, 1e-10);
    let rel = (pn.objective - owl.objective).abs() / owl.objective.abs().max(1e-12);
    assert!(
        rel <= 1e-6,
        "objectives disagree: prox-Newton {} vs OWL-QN {} (rel {rel:.2e})",
        pn.objective,
        owl.objective
    );
}

#[test]
fn prox_newton_matches_lbfgs_objective_on_l1_probit() {
    let ds = probit_correlated(
        CorrelatedSpec { n: 150, p: 80, rho: 0.3, nnz: 6, snr: 0.0 },
        13,
    );
    let lam = glm_lambda_max(&Probit::new(), &ds.design, &ds.y) / 10.0;
    let mut f1 = Probit::new();
    let pn = solve_prox_newton(
        &ds.design,
        &ds.y,
        &mut f1,
        &L1::new(lam),
        &SolverOpts::default().with_tol(1e-10),
        None,
    );
    assert!(pn.converged, "prox-Newton kkt = {}", pn.kkt);
    let mut f2 = Probit::new();
    let owl = solve_owlqn(&ds.design, &ds.y, &mut f2, lam, 10, 10_000, 1e-10);
    let rel = (pn.objective - owl.objective).abs() / owl.objective.abs().max(1e-12);
    assert!(rel <= 1e-6, "prox-Newton {} vs OWL-QN {} (rel {rel:.2e})", pn.objective, owl.objective);
}

#[test]
fn cv_selection_is_anchored_per_training_fold() {
    // leakage regression at the integration level: with one extreme
    // validation-only row, CV must still pick a sensible interior λ and
    // report training-only fold anchors
    let mut ds = correlated(CorrelatedSpec { n: 80, p: 40, rho: 0.3, nnz: 4, snr: 10.0 }, 5);
    ds.y[0] *= 30.0;
    let ratios = geometric_grid(1e-3, 8);
    let cv = skglm::estimators::lasso_cv(
        &ds,
        &ratios,
        4,
        &SolverOpts::default().with_tol(1e-8),
        1,
        2,
    );
    assert!(cv.cv_mse.iter().all(|m| m.is_finite()));
    assert_eq!(cv.fold_lambda_max.len(), 4);
    let spread = cv.fold_lambda_max.iter().cloned().fold(0.0f64, f64::max)
        / cv.fold_lambda_max.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread > 1.0 + 1e-9, "fold anchors identical — per-fold λ_max not in effect");
    assert!(cv.best_lambda > 0.0);
}
