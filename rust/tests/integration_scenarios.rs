//! Integration: the scenario conformance subsystem end-to-end — the CI
//! smoke subset runs through `conform()` against a redirected results
//! dir, and the repo-root `scenarios.jsonl` corpus is proven to stay in
//! sync with the compiled-in fallback.
//!
//! The conform run lives in ONE test fn: it mutates process-global state
//! (the kernel thread budget via each variant run, `SKGLM_RESULTS` for
//! result redirection), so it must not race sibling tests. The corpus
//! cross-checks are pure parsing and may run in parallel with it.

use skglm::bench::scenario::{builtin_corpus, conform, parse_corpus};
use skglm::util::json::Json;

fn repo_root_corpus() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../scenarios.jsonl");
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

#[test]
fn repo_corpus_file_matches_builtin_corpus() {
    let parsed = parse_corpus(&repo_root_corpus()).expect("scenarios.jsonl must parse");
    let builtin = builtin_corpus();
    assert_eq!(
        parsed.len(),
        builtin.len(),
        "scenarios.jsonl and builtin_corpus() drifted apart (counts differ)"
    );
    for (file, code) in parsed.iter().zip(builtin.iter()) {
        assert_eq!(file, code, "scenario {:?} differs between file and code", code.id);
    }
}

#[test]
fn corpus_meets_the_issue_floor() {
    let c = builtin_corpus();
    assert!(c.len() >= 30, "only {} scenarios", c.len());
    let smoke: Vec<_> = c.iter().filter(|s| s.smoke).collect();
    assert!(smoke.len() >= 6, "smoke subset too small to gate CI: {}", smoke.len());
}

#[test]
fn conform_smoke_runs_green_and_emits_structured_results() {
    // redirect results away from the repo root (also suppresses the
    // repo-root BENCH_scenarios.json copy, per the BENCH convention)
    let tmp = std::env::temp_dir().join(format!("skglm_conform_{}", std::process::id()));
    std::env::set_var("SKGLM_RESULTS", &tmp);

    let written = conform(None, None, true).expect("smoke conformance subset must pass");

    // one JSON per smoke scenario + the aggregate, all under the redirect
    let n_smoke = builtin_corpus().iter().filter(|s| s.smoke).count();
    assert_eq!(written.len(), n_smoke + 1, "{written:?}");
    for p in &written {
        assert!(p.starts_with(&tmp), "{} escaped the results redirect", p.display());
        assert!(p.exists(), "{}", p.display());
    }

    // the aggregate is a valid AgentLab-style report: counts + per-row
    // scenario_id / outcome / objective / metrics / violations
    let agg_path = tmp.join("scenarios").join("BENCH_scenarios.json");
    let agg = Json::parse(&std::fs::read_to_string(&agg_path).unwrap()).unwrap();
    assert_eq!(agg.get("total").and_then(Json::as_usize), Some(n_smoke));
    assert_eq!(agg.get("fail").and_then(Json::as_usize), Some(0));
    let rows = agg.get("scenarios").and_then(Json::as_arr).expect("scenarios array");
    assert_eq!(rows.len(), n_smoke);
    for row in rows {
        let id = row.get("scenario_id").and_then(Json::as_str).expect("scenario_id");
        assert_eq!(row.get("outcome").and_then(Json::as_str), Some("pass"), "{id}");
        assert!(
            row.get("objective").and_then(Json::as_f64).map(f64::is_finite).unwrap_or(false),
            "{id}: objective must be finite"
        );
        let metrics = row.get("metrics").expect("metrics object");
        assert!(
            metrics.get("kkt_final").and_then(Json::as_f64).is_some(),
            "{id}: kkt_final missing"
        );
        assert!(
            metrics.get("certificate").and_then(Json::as_str).is_some(),
            "{id}: certificate missing"
        );
        assert_eq!(
            row.get("violations").and_then(Json::as_arr).map(|a| a.len()),
            Some(0),
            "{id}: passing scenario must have no violations"
        );
    }

    std::env::remove_var("SKGLM_RESULTS");
    let _ = std::fs::remove_dir_all(&tmp);
}
