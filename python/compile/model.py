"""L2: the JAX compute graph the Rust coordinator calls through PJRT.

The paper's algorithmic hot spot at L3 is the working-set scoring pass —
the only O(n·p) operation per outer iteration. This module expresses it
as jitted JAX functions wrapping the L1 Pallas kernels:

- ``grad_quadratic``  — ∇f(β) = Xᵀr/n  (artifact ``xt_r``; consumed by the
  Rust ``PjrtGradEngine``),
- ``score_l1_pass`` / ``score_mcp_pass`` — fused gradient + Eq.-(2) score
  (artifacts ``score_l1`` / ``score_mcp``),
- ``prox_bank`` — batched proximal operators for full-vector steps.

Shapes are static at lowering time (one artifact per (n, p)); the 1/n
normalisation is baked in. Python never runs at solve time — aot.py lowers
these once to HLO text.
"""

import jax
import jax.numpy as jnp

from .kernels import matvec, prox, score

# Kernel schedules (EXPERIMENTS.md §Perf / ARCHITECTURE.md §Hardware-Adaptation):
#   - "tpu": (128, 512) tiles — MXU-aligned, 262 KiB/step VMEM, the layout
#     a real TPU deployment streams HBM→VMEM with. This is what the kernel
#     is *written for*.
#   - "cpu": whole-array blocks. interpret=True executes each grid step as
#     a data-copying loop iteration costing ~3 ms on this CPU, so the AOT
#     artifacts (which run on CPU PJRT) minimise grid steps: measured
#     106 ms → 0.43 ms for the 2000×1000 scoring pass (245×; §Perf).
#     The kernel body is identical — only BlockSpec parameters change.
SCHEDULES = {
    "tpu": (128, 512),
    "cpu": (1 << 30, 1 << 30),  # _pick_block clamps to the full dimension
}


def _blocks(schedule: str):
    try:
        return SCHEDULES[schedule]
    except KeyError:
        raise ValueError(f"unknown schedule {schedule!r}") from None


def grad_quadratic(xt, r, *, schedule: str = "cpu"):
    """∇f(β) = Xᵀ r / n for the quadratic datafit.

    xt: f32[p, n] (Xᵀ — bit-identical to Rust's column-major X), r: f32[n]
    (the residual Xβ − y maintained by the Rust solver). Returns f32[p].
    """
    bp, bn = _blocks(schedule)
    inv_n = 1.0 / xt.shape[1]
    return matvec.xt_r(xt, r, block_p=bp, block_n=bn) * inv_n


def score_l1_pass(xt, r, beta, lam, *, schedule: str = "cpu"):
    """Fused (grad, score^∂) for g = λ|·| (paper Eq. 2). lam: f32[1]."""
    bp, bn = _blocks(schedule)
    inv_n = 1.0 / xt.shape[1]
    # fold 1/n into the residual so the fused kernel's epilogue sees the
    # correctly-scaled gradient (one multiply on the [n] vector instead of
    # [p] postprocessing)
    grad, sc = score.score_l1(xt, r * inv_n, beta, lam, block_p=bp, block_n=bn)
    return grad, sc


def score_mcp_pass(xt, r, beta, params, *, schedule: str = "cpu"):
    """Fused (grad, score^∂) for the MCP. params = [λ, γ] (f32[2])."""
    bp, bn = _blocks(schedule)
    inv_n = 1.0 / xt.shape[1]
    grad, sc = score.score_mcp(xt, r * inv_n, beta, params, block_p=bp, block_n=bn)
    return grad, sc


def prox_bank(kind: str):
    """Batched prox for full-vector steps: kind ∈ {l1, mcp, scad}."""
    return {"l1": prox.prox_l1, "mcp": prox.prox_mcp, "scad": prox.prox_scad}[kind]


def objective_quadratic_l1(xt, r, beta, lam):
    """Φ(β) = ‖r‖²/2n + λ‖β‖₁ — used by the extrapolation-guard artifact."""
    inv_n = 1.0 / xt.shape[1]
    return 0.5 * inv_n * jnp.sum(r * r) + lam[0] * jnp.sum(jnp.abs(beta))


def lower_entry(op: str, n: int, p: int):
    """Return (fn, example_args) for an artifact entry point."""
    f32 = jnp.float32
    xt = jax.ShapeDtypeStruct((p, n), f32)
    r = jax.ShapeDtypeStruct((n,), f32)
    beta = jax.ShapeDtypeStruct((p,), f32)
    if op == "xt_r":
        return (lambda xt, r: (grad_quadratic(xt, r),)), (xt, r)
    if op == "score_l1":
        lam = jax.ShapeDtypeStruct((1,), f32)
        return (lambda *a: tuple(score_l1_pass(*a))), (xt, r, beta, lam)
    if op == "score_mcp":
        params = jax.ShapeDtypeStruct((2,), f32)
        return (lambda *a: tuple(score_mcp_pass(*a))), (xt, r, beta, params)
    if op == "obj_l1":
        lam = jax.ShapeDtypeStruct((1,), f32)
        return (lambda *a: (objective_quadratic_l1(*a),)), (xt, r, beta, lam)
    raise ValueError(f"unknown artifact op {op!r}")
