"""L1 Pallas kernel: the tiled `Xᵀr` scoring pass.

This is the O(n·p) hot spot of the paper's Algorithm 1: every outer
iteration recomputes the full gradient `∇f(β) = Xᵀ(Xβ−y)/n` to rank
features. On TPU this is a matvec streamed through VMEM:

- `Xᵀ` arrives as a [p, n] array (the Rust design matrix is column-major
  [n, p], which is bit-identical to row-major [p, n] — zero-copy across
  the FFI boundary);
- the grid is (p/bp, n/bn); each step loads a (bp, bn) tile of `Xᵀ` and a
  (bn,) slice of `r` into VMEM and accumulates `tile @ r_slice` into the
  (bp,) output block — an MXU-shaped contraction with f32 accumulation;
- the n-axis is the reduction axis: the output block is zeroed at the
  first n-step and accumulated across the rest ("revisiting" grid
  semantics).

Hardware adaptation (ARCHITECTURE.md §Hardware-Adaptation): the paper's numba
CPU kernels become BlockSpec-scheduled VMEM tiles; block sizes target MXU
alignment (multiples of 128) with graceful fallback for small test shapes.

interpret=True ALWAYS — real-TPU lowering emits a Mosaic custom-call that
the CPU PJRT plugin cannot execute (see ARCHITECTURE.md §PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (MXU-aligned when the
    shape allows it; exact-divisor fallback keeps interpret-mode indexing
    simple for the small pytest shapes)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


def _xt_r_kernel(xt_ref, r_ref, o_ref):
    """One (bp, bn) tile: o[bp] += Xᵀ-tile @ r-slice, zeroed at n-step 0."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (bp, bn) @ (bn,) -> (bp,); jnp.dot on f32 tiles maps to the MXU
    o_ref[...] += jnp.dot(xt_ref[...], r_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_p", "block_n"))
def xt_r(xt, r, *, block_p: int = 128, block_n: int = 512):
    """`Xᵀ r` via the tiled Pallas kernel. xt: f32[p, n], r: f32[n] → f32[p].

    NOTE: returns the *unnormalised* product; the L2 model layer applies
    the 1/n factor (kept separate so the same kernel serves every datafit).
    """
    p, n = xt.shape
    assert r.shape == (n,), f"residual shape {r.shape} != ({n},)"
    bp = _pick_block(p, block_p)
    bn = _pick_block(n, block_n)
    grid = (p // bp, n // bn)
    return pl.pallas_call(
        _xt_r_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xt, r)


def vmem_bytes(block_p: int, block_n: int) -> int:
    """VMEM footprint of one grid step (f32): Xᵀ tile + r slice + out block.

    Used by EXPERIMENTS.md §Perf to check the schedule fits the ~16 MiB/core
    VMEM budget on real TPUs.
    """
    return 4 * (block_p * block_n + block_n + block_p)


def mxu_utilization_estimate(p: int, n: int, block_p: int, block_n: int) -> float:
    """Fraction of MXU-aligned work: how much of each (bp, bn) tile is
    'real' when padded up to 128×128 systolic passes. 1.0 = perfectly
    aligned tiles."""
    pad = lambda b: -(-b // 128) * 128
    return (block_p * block_n) / (pad(block_p) * pad(block_n))
