"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite compares every kernel against
(the paper's working-set scoring math, written in the most obvious way).
"""

import jax.numpy as jnp


def xt_r_ref(xt, r, inv_n):
    """Full-gradient scoring pass: grad = Xᵀ r / n, with xt = Xᵀ [p, n]."""
    return (xt @ r) * inv_n


def score_l1_ref(xt, r, beta, lam, inv_n):
    """Fused L1 working-set score (paper Eq. 2 for g = λ|·|).

    Returns (grad, score) where
      score_j = max(|grad_j| - λ, 0)        if β_j == 0
              = |grad_j + λ sign(β_j)|      otherwise.
    """
    grad = xt_r_ref(xt, r, inv_n)
    at_zero = jnp.maximum(jnp.abs(grad) - lam, 0.0)
    away = jnp.abs(grad + lam * jnp.sign(beta))
    return grad, jnp.where(beta == 0.0, at_zero, away)


def score_mcp_ref(xt, r, beta, lam, gamma, inv_n):
    """Fused MCP working-set score (paper Eq. 2).

    score_j = max(|grad_j| - λ, 0)                 if β_j == 0
            = |grad_j + λ sign(β_j) - β_j/γ|       if 0 < |β_j| < γλ
            = |grad_j|                             otherwise.
    """
    grad = xt_r_ref(xt, r, inv_n)
    at_zero = jnp.maximum(jnp.abs(grad) - lam, 0.0)
    mid = jnp.abs(grad + lam * jnp.sign(beta) - beta / gamma)
    flat = jnp.abs(grad)
    score = jnp.where(
        beta == 0.0, at_zero, jnp.where(jnp.abs(beta) < gamma * lam, mid, flat)
    )
    return grad, score


def soft_threshold_ref(v, t):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0)


def prox_l1_ref(v, step, lam):
    """Elementwise prox of step·λ|·|."""
    return soft_threshold_ref(v, step * lam)


def prox_mcp_ref(v, step, lam, gamma):
    """Elementwise firm threshold: prox of step·MCP_{λ,γ} (γ > step)."""
    a = jnp.abs(v)
    tau = step * lam
    firm = jnp.sign(v) * (a - tau) / (1.0 - step / gamma)
    return jnp.where(a <= tau, 0.0, jnp.where(a <= gamma * lam, firm, v))


def prox_scad_ref(v, step, lam, gamma):
    """Elementwise prox of step·SCAD_{λ,γ} (γ > 1 + step)."""
    a = jnp.abs(v)
    soft = soft_threshold_ref(v, step * lam)
    mid = ((gamma - 1.0) * v - jnp.sign(v) * step * gamma * lam) / (gamma - 1.0 - step)
    return jnp.where(
        a <= lam * (1.0 + step), soft, jnp.where(a <= gamma * lam, mid, v)
    )


def quad_objective_ref(r, inv_n):
    """Quadratic datafit value from the residual: ‖r‖²/(2n)."""
    return 0.5 * inv_n * jnp.sum(r * r)
