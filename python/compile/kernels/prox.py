"""L1 Pallas kernels: vectorised proximal operators (VPU elementwise).

The batched prox bank — applied to a whole coefficient block at once — is
what a TPU deployment of proximal *gradient* steps (ISTA/FISTA baselines)
or of the extrapolation guard would run. Each kernel is a pure
elementwise map over 1-D blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matvec import _pick_block


def _soft(v, t):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0)


def _prox_l1_kernel(v_ref, params_ref, o_ref):
    step, lam = params_ref[0], params_ref[1]
    o_ref[...] = _soft(v_ref[...], step * lam)


def _prox_mcp_kernel(v_ref, params_ref, o_ref):
    step, lam, gamma = params_ref[0], params_ref[1], params_ref[2]
    v = v_ref[...]
    a = jnp.abs(v)
    tau = step * lam
    firm = jnp.sign(v) * (a - tau) / (1.0 - step / gamma)
    o_ref[...] = jnp.where(a <= tau, 0.0, jnp.where(a <= gamma * lam, firm, v))


def _prox_scad_kernel(v_ref, params_ref, o_ref):
    step, lam, gamma = params_ref[0], params_ref[1], params_ref[2]
    v = v_ref[...]
    a = jnp.abs(v)
    soft = _soft(v, step * lam)
    mid = ((gamma - 1.0) * v - jnp.sign(v) * step * gamma * lam) / (
        gamma - 1.0 - step
    )
    o_ref[...] = jnp.where(
        a <= lam * (1.0 + step), soft, jnp.where(a <= gamma * lam, mid, v)
    )


def _elementwise_call(kernel, v, params, block: int):
    (p,) = v.shape
    b = _pick_block(p, block)
    return pl.pallas_call(
        kernel,
        grid=(p // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((params.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(v, params)


@functools.partial(jax.jit, static_argnames=("block",))
def prox_l1(v, params, *, block: int = 1024):
    """Soft threshold. params = [step, λ]."""
    return _elementwise_call(_prox_l1_kernel, v, params, block)


@functools.partial(jax.jit, static_argnames=("block",))
def prox_mcp(v, params, *, block: int = 1024):
    """Firm threshold (MCP). params = [step, λ, γ], valid for γ > step."""
    return _elementwise_call(_prox_mcp_kernel, v, params, block)


@functools.partial(jax.jit, static_argnames=("block",))
def prox_scad(v, params, *, block: int = 1024):
    """SCAD prox. params = [step, λ, γ], valid for γ > 1 + step."""
    return _elementwise_call(_prox_scad_kernel, v, params, block)
