"""Pallas kernels (L1) + pure-jnp oracles (ref.py)."""
