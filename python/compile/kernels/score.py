"""L1 Pallas kernels: fused gradient + working-set score.

The paper's Eq. (2) score needs, per feature, the gradient AND the
distance to the subdifferential. Computing them in one kernel keeps the
gradient tile in VMEM for the (VPU, elementwise) score epilogue instead of
round-tripping through HBM — the fusion a production TPU deployment would
use. The epilogue runs on the *last* n-step of each p-row, when the
accumulated gradient block is complete.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matvec import _pick_block


def _score_l1_kernel(n_steps, xt_ref, r_ref, beta_ref, lam_ref, grad_ref, score_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        score_ref[...] = jnp.zeros_like(score_ref)

    grad_ref[...] += jnp.dot(
        xt_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == n_steps - 1)
    def _epilogue():
        lam = lam_ref[0]
        grad = grad_ref[...]
        beta = beta_ref[...]
        at_zero = jnp.maximum(jnp.abs(grad) - lam, 0.0)
        away = jnp.abs(grad + lam * jnp.sign(beta))
        score_ref[...] = jnp.where(beta == 0.0, at_zero, away)


@functools.partial(jax.jit, static_argnames=("block_p", "block_n"))
def score_l1(xt, r, beta, lam, *, block_p: int = 128, block_n: int = 512):
    """Fused (grad, score^∂) for the L1 penalty.

    xt: f32[p, n] (= Xᵀ, pre-scaled by 1/n by the caller or not — the
    score is computed on whatever gradient scale comes in), r: f32[n],
    beta: f32[p], lam: f32[1]. Returns (grad f32[p], score f32[p]).
    """
    p, n = xt.shape
    bp = _pick_block(p, block_p)
    bn = _pick_block(n, block_n)
    grid = (p // bp, n // bn)
    kernel = functools.partial(_score_l1_kernel, grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bp,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i, j: (i,)),
            pl.BlockSpec((bp,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=True,
    )(xt, r, beta, lam)


def _score_mcp_kernel(
    n_steps, xt_ref, r_ref, beta_ref, params_ref, grad_ref, score_ref
):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        score_ref[...] = jnp.zeros_like(score_ref)

    grad_ref[...] += jnp.dot(
        xt_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == n_steps - 1)
    def _epilogue():
        lam = params_ref[0]
        gamma = params_ref[1]
        grad = grad_ref[...]
        beta = beta_ref[...]
        at_zero = jnp.maximum(jnp.abs(grad) - lam, 0.0)
        mid = jnp.abs(grad + lam * jnp.sign(beta) - beta / gamma)
        flat = jnp.abs(grad)
        score_ref[...] = jnp.where(
            beta == 0.0, at_zero, jnp.where(jnp.abs(beta) < gamma * lam, mid, flat)
        )


@functools.partial(jax.jit, static_argnames=("block_p", "block_n"))
def score_mcp(xt, r, beta, params, *, block_p: int = 128, block_n: int = 512):
    """Fused (grad, score^∂) for the MCP penalty. params = [λ, γ] (f32[2])."""
    p, n = xt.shape
    bp = _pick_block(p, block_p)
    bn = _pick_block(n, block_n)
    grid = (p // bp, n // bn)
    kernel = functools.partial(_score_mcp_kernel, grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bp,), lambda i, j: (i,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i, j: (i,)),
            pl.BlockSpec((bp,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=True,
    )(xt, r, beta, params)
