"""AOT lowering: JAX/Pallas (L2/L1) → HLO text → artifacts/.

Run once at build time (``make artifacts``). Emits one
``<op>_n{n}_p{p}.hlo.txt`` per (op, shape) — the naming convention the
Rust runtime (`runtime::client::artifact_path`) resolves.

HLO **text** is the interchange format, NOT ``lowered.serialize()``:
the image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
(64-bit instruction ids, ``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See ARCHITECTURE.md §PJRT.

Usage: ``python -m compile.aot [--out-dir ../artifacts] [--check]``.
"""

import argparse
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# (op, n, p) artifact matrix:
#   - (200, 400): integration-test shape (rust/tests/integration_runtime)
#   - (1000, 2000): the Figure-1 dense workload
#   - (1000, 5000): the Figure-5 dense MCP workload
SHAPES = [(200, 400), (1000, 2000), (1000, 5000)]
OPS = ["xt_r", "score_l1", "score_mcp", "obj_l1"]


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple so the Rust
    side unwraps with to_tuple1/to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(op: str, n: int, p: int) -> str:
    fn, args = model.lower_entry(op, n, p)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def build(out_dir: pathlib.Path, shapes=None, ops=None, force=False) -> list[pathlib.Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for n, p in shapes or SHAPES:
        for op in ops or OPS:
            path = out_dir / f"{op}_n{n}_p{p}.hlo.txt"
            if path.exists() and not force:
                continue
            text = lower_artifact(op, n, p)
            assert text.startswith("HloModule"), f"unexpected HLO header for {op}"
            path.write_text(text)
            written.append(path)
            print(f"[aot] wrote {path} ({len(text)} chars)")
    return written


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="rebuild even if present")
    ap.add_argument(
        "--check", action="store_true", help="verify numerics of lowered fns vs ref"
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    written = build(out, force=args.force)
    if not written:
        print("[aot] artifacts up to date")
    if args.check:
        _check()
    return 0


def _check():
    """Spot-check the lowered xt_r against the jnp oracle."""
    import numpy as np

    from .kernels import ref

    rng = np.random.default_rng(0)
    n, p = 200, 400
    xt = np.asarray(rng.normal(size=(p, n)), dtype=np.float32)
    r = np.asarray(rng.normal(size=n), dtype=np.float32)
    fn, _ = model.lower_entry("xt_r", n, p)
    (got,) = jax.jit(fn)(xt, r)
    want = ref.xt_r_ref(xt, r, 1.0 / n)
    err = float(abs(got - want).max())
    assert err < 1e-5, f"xt_r check failed: {err}"
    print(f"[aot] numeric check ok (max err {err:.2e})")


if __name__ == "__main__":
    sys.exit(main())
