"""Property-based sweeps (hypothesis): kernel-vs-oracle equality over
randomised shapes, block sizes and value distributions — the broad net
behind the hand-picked cases in test_kernels.py."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec, prox, ref, score

SET = settings(max_examples=25, deadline=None)


def np_floats(shape, seed, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32)


@st.composite
def matvec_case(draw):
    p = draw(st.integers(min_value=1, max_value=96))
    n = draw(st.integers(min_value=1, max_value=96))
    bp = draw(st.integers(min_value=1, max_value=128))
    bn = draw(st.integers(min_value=1, max_value=128))
    seed = draw(st.integers(min_value=0, max_value=2**31))
    scale = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    return p, n, bp, bn, seed, scale


@SET
@given(matvec_case())
def test_xt_r_matches_ref_for_any_shape(case):
    p, n, bp, bn, seed, scale = case
    xt = np_floats((p, n), seed, scale)
    r = np_floats((n,), seed + 1, scale)
    got = matvec.xt_r(xt, r, block_p=bp, block_n=bn)
    want = ref.xt_r_ref(xt, r, 1.0)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4 * scale * scale * n)


@SET
@given(matvec_case(), st.floats(min_value=1e-4, max_value=10.0))
def test_score_l1_matches_ref_for_any_shape(case, lam):
    p, n, bp, bn, seed, _ = case
    xt = np_floats((p, n), seed, 1.0)
    r = np_floats((n,), seed + 1, 1.0)
    beta = np_floats((p,), seed + 2, 1.0)
    # sparsify beta so both score branches are exercised
    beta = jnp.where(jnp.abs(beta) < 0.5, 0.0, beta)
    g, s = score.score_l1(
        xt, r, beta, jnp.array([lam], jnp.float32), block_p=bp, block_n=bn
    )
    ge, se = ref.score_l1_ref(xt, r, beta, lam, 1.0)
    np.testing.assert_allclose(g, ge, rtol=5e-4, atol=5e-4 * n)
    np.testing.assert_allclose(s, se, rtol=5e-4, atol=5e-4 * n)


@SET
@given(
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=1, max_value=64),
    st.floats(min_value=0.05, max_value=2.0),
    st.floats(min_value=0.01, max_value=3.0),
    st.integers(min_value=0, max_value=2**31),
)
def test_prox_l1_matches_ref(p, block, step, lam, seed):
    v = np_floats((p,), seed, 3.0)
    got = prox.prox_l1(v, jnp.array([step, lam], jnp.float32), block=block)
    want = ref.prox_l1_ref(v, step, lam)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@SET
@given(
    st.integers(min_value=1, max_value=300),
    st.floats(min_value=0.05, max_value=1.5),
    st.floats(min_value=0.01, max_value=2.0),
    st.floats(min_value=2.0, max_value=10.0),
    st.integers(min_value=0, max_value=2**31),
)
def test_prox_mcp_matches_ref_in_semiconvex_regime(p, step, lam, gamma, seed):
    # gamma > step guaranteed by the strategy bounds
    v = np_floats((p,), seed, 3.0 * gamma * lam)
    got = prox.prox_mcp(v, jnp.array([step, lam, gamma], jnp.float32))
    want = ref.prox_mcp_ref(v, step, lam, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@SET
@given(
    st.integers(min_value=1, max_value=300),
    st.floats(min_value=0.05, max_value=1.5),
    st.floats(min_value=0.01, max_value=2.0),
    st.floats(min_value=3.0, max_value=10.0),
    st.integers(min_value=0, max_value=2**31),
)
def test_prox_scad_matches_ref_in_semiconvex_regime(p, step, lam, gamma, seed):
    v = np_floats((p,), seed, 3.0 * gamma * lam)
    got = prox.prox_scad(v, jnp.array([step, lam, gamma], jnp.float32))
    want = ref.prox_scad_ref(v, step, lam, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@SET
@given(
    st.integers(min_value=1, max_value=200),
    st.floats(min_value=0.05, max_value=1.5),
    st.floats(min_value=0.01, max_value=2.0),
    st.integers(min_value=0, max_value=2**31),
)
def test_prox_l1_is_nonexpansive(p, step, lam, seed):
    # ‖prox(u) − prox(v)‖ ≤ ‖u − v‖ for convex penalties
    u = np_floats((p,), seed, 2.0)
    v = np_floats((p,), seed + 1, 2.0)
    params = jnp.array([step, lam], jnp.float32)
    pu = prox.prox_l1(u, params)
    pv = prox.prox_l1(v, params)
    lhs = float(jnp.linalg.norm(pu - pv))
    rhs = float(jnp.linalg.norm(u - v))
    assert lhs <= rhs + 1e-5 * (1.0 + rhs)
