"""L1 correctness: every Pallas kernel vs its pure-jnp oracle (ref.py).

This is the CORE correctness signal of the compile path — the Rust solver
trusts these kernels through the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import matvec, prox, ref, score

RTOL = 2e-5
ATOL = 2e-5


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32)


def sparse_beta(p, seed, frac=0.3):
    rng = np.random.default_rng(seed)
    mask = rng.random(p) < frac
    return jnp.asarray(rng.normal(size=p) * mask, dtype=jnp.float32)


SHAPES = [(8, 16), (24, 40), (128, 256), (200, 144), (96, 200), (1, 8), (7, 13)]


class TestXtR:
    @pytest.mark.parametrize("p,n", SHAPES)
    def test_matches_ref(self, p, n):
        xt = rand((p, n), 1)
        r = rand((n,), 2)
        got = matvec.xt_r(xt, r, block_p=64, block_n=64)
        want = ref.xt_r_ref(xt, r, 1.0)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("bp,bn", [(1, 1), (8, 16), (128, 512)])
    def test_block_size_invariance(self, bp, bn):
        xt = rand((32, 48), 3)
        r = rand((48,), 4)
        got = matvec.xt_r(xt, r, block_p=bp, block_n=bn)
        want = ref.xt_r_ref(xt, r, 1.0)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_zero_residual_gives_zero_gradient(self):
        xt = rand((16, 24), 5)
        out = matvec.xt_r(xt, jnp.zeros(24, jnp.float32))
        assert float(jnp.max(jnp.abs(out))) == 0.0

    def test_large_values_stay_finite(self):
        xt = rand((16, 24), 6, scale=1e4)
        r = rand((24,), 7, scale=1e4)
        out = matvec.xt_r(xt, r)
        assert bool(jnp.all(jnp.isfinite(out)))


class TestScoreL1:
    @pytest.mark.parametrize("p,n", SHAPES)
    def test_matches_ref(self, p, n):
        xt = rand((p, n), 11)
        r = rand((n,), 12)
        beta = sparse_beta(p, 13)
        lam = jnp.array([0.37], jnp.float32)
        g, s = score.score_l1(xt, r, beta, lam, block_p=64, block_n=64)
        ge, se = ref.score_l1_ref(xt, r, beta, 0.37, 1.0)
        np.testing.assert_allclose(g, ge, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(s, se, rtol=RTOL, atol=ATOL)

    def test_score_zero_at_kkt_point(self):
        # craft grad = -lam*sign(beta) exactly on the support
        p, n = 4, 4
        xt = jnp.eye(p, n, dtype=jnp.float32)
        beta = jnp.array([1.0, -2.0, 0.0, 0.0], jnp.float32)
        lam = 0.5
        r = jnp.array([-lam, lam, 0.1, -0.2], jnp.float32)  # grad = r here
        _, s = score.score_l1(xt, r, beta, jnp.array([lam], jnp.float32))
        np.testing.assert_allclose(s, 0.0, atol=1e-6)

    def test_all_zero_beta_uses_at_zero_branch(self):
        xt = rand((16, 8), 14)
        r = rand((8,), 15)
        lam = jnp.array([10.0], jnp.float32)  # lam > every |grad|
        _, s = score.score_l1(xt, r, jnp.zeros(16, jnp.float32), lam)
        np.testing.assert_allclose(s, 0.0, atol=1e-6)


class TestScoreMcp:
    @pytest.mark.parametrize("p,n", SHAPES)
    def test_matches_ref(self, p, n):
        xt = rand((p, n), 21)
        r = rand((n,), 22)
        beta = sparse_beta(p, 23) * 3.0  # hit all three regions
        params = jnp.array([0.4, 3.0], jnp.float32)
        g, s = score.score_mcp(xt, r, beta, params, block_p=64, block_n=64)
        ge, se = ref.score_mcp_ref(xt, r, beta, 0.4, 3.0, 1.0)
        np.testing.assert_allclose(g, ge, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(s, se, rtol=RTOL, atol=ATOL)

    def test_flat_region_score_is_grad_magnitude(self):
        p, n = 4, 4
        xt = jnp.eye(p, n, dtype=jnp.float32)
        r = jnp.array([0.3, -0.4, 0.0, 0.0], jnp.float32)
        beta = jnp.array([100.0, -50.0, 0.0, 0.0], jnp.float32)  # far past γλ
        params = jnp.array([0.5, 3.0], jnp.float32)
        _, s = score.score_mcp(xt, r, beta, params)
        np.testing.assert_allclose(s[:2], jnp.abs(r[:2]), rtol=1e-6)


class TestProx:
    @pytest.mark.parametrize("p", [8, 100, 1024, 37])
    def test_l1_matches_ref(self, p):
        v = rand((p,), 31, scale=2.0)
        params = jnp.array([0.7, 0.3], jnp.float32)
        got = prox.prox_l1(v, params, block=64)
        want = ref.prox_l1_ref(v, 0.7, 0.3)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("p", [8, 100, 1024, 37])
    def test_mcp_matches_ref(self, p):
        v = rand((p,), 32, scale=3.0)
        params = jnp.array([0.9, 0.5, 3.0], jnp.float32)
        got = prox.prox_mcp(v, params, block=64)
        want = ref.prox_mcp_ref(v, 0.9, 0.5, 3.0)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("p", [8, 100, 1024, 37])
    def test_scad_matches_ref(self, p):
        v = rand((p,), 33, scale=4.0)
        params = jnp.array([0.8, 0.5, 3.7], jnp.float32)
        got = prox.prox_scad(v, params, block=64)
        want = ref.prox_scad_ref(v, 0.8, 0.5, 3.7)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_prox_mcp_dead_zone_and_identity(self):
        params = jnp.array([1.0, 0.5, 3.0], jnp.float32)
        v = jnp.array([0.3, -0.3, 5.0, -5.0], jnp.float32)
        out = prox.prox_mcp(v, params)
        assert out[0] == 0.0 and out[1] == 0.0
        assert out[2] == 5.0 and out[3] == -5.0

    def test_prox_l1_shrinks_toward_zero(self):
        params = jnp.array([1.0, 0.5], jnp.float32)
        v = rand((64,), 34)
        out = prox.prox_l1(v, params)
        assert bool(jnp.all(jnp.abs(out) <= jnp.abs(v) + 1e-7))


class TestHelpers:
    def test_pick_block_divides(self):
        for dim in [1, 7, 128, 200, 1000]:
            for pref in [1, 8, 128, 512]:
                b = matvec._pick_block(dim, pref)
                assert dim % b == 0
                assert 1 <= b <= max(pref, 1)

    def test_vmem_budget_for_paper_shapes(self):
        # production schedule must fit in ~16 MiB VMEM
        assert matvec.vmem_bytes(128, 512) < 16 * 2**20

    def test_mxu_utilization_perfect_for_aligned_tiles(self):
        assert matvec.mxu_utilization_estimate(2000, 1000, 128, 512) == 1.0
        assert matvec.mxu_utilization_estimate(200, 100, 8, 100) < 0.1
