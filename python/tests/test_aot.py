"""AOT path: lowering produces loadable HLO text with the right interface."""

import pathlib
import tempfile

import pytest

from compile import aot, model


class TestLowering:
    def test_hlo_text_header_and_shapes(self):
        text = aot.lower_artifact("xt_r", 16, 24)
        assert text.startswith("HloModule")
        # parameter and result shapes must appear in the text
        assert "f32[24,16]" in text, "Xᵀ parameter shape"
        assert "f32[16]" in text, "residual parameter shape"
        assert "f32[24]" in text, "gradient output shape"

    def test_fused_score_has_two_outputs(self):
        text = aot.lower_artifact("score_l1", 16, 24)
        assert text.startswith("HloModule")
        # tuple of two f32[p] outputs
        assert text.count("f32[24]") >= 2

    def test_lowering_is_deterministic(self):
        a = aot.lower_artifact("obj_l1", 8, 8)
        b = aot.lower_artifact("obj_l1", 8, 8)
        assert a == b


class TestBuild:
    def test_build_writes_and_skips_existing(self):
        with tempfile.TemporaryDirectory() as d:
            out = pathlib.Path(d)
            written = aot.build(out, shapes=[(8, 16)], ops=["xt_r"])
            assert len(written) == 1
            assert written[0].name == "xt_r_n8_p16.hlo.txt"
            assert written[0].read_text().startswith("HloModule")
            # second run: up to date, nothing written
            assert aot.build(out, shapes=[(8, 16)], ops=["xt_r"]) == []
            # force rebuilds
            assert len(aot.build(out, shapes=[(8, 16)], ops=["xt_r"], force=True)) == 1

    def test_default_matrix_covers_runtime_test_shape(self):
        # the Rust integration test loads (200, 400); it must be in SHAPES
        assert (200, 400) in aot.SHAPES
        assert "xt_r" in aot.OPS


class TestEntryConsistency:
    @pytest.mark.parametrize("op", aot.OPS)
    def test_every_default_op_lowers(self, op):
        fn, args = model.lower_entry(op, 8, 16)
        assert callable(fn)
        assert len(args) >= 2
