"""L2 correctness: the jitted model functions and artifact entry points."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32)


class TestGradQuadratic:
    def test_normalisation_baked_in(self):
        xt = rand((40, 24), 0)
        r = rand((24,), 1)
        got = model.grad_quadratic(xt, r)
        want = ref.xt_r_ref(xt, r, 1.0 / 24)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)

    def test_matches_dense_lstsq_gradient(self):
        # gradient of ||y - Xb||^2/2n at b: X^T(Xb - y)/n
        rng = np.random.default_rng(2)
        n, p = 30, 12
        x = rng.normal(size=(n, p)).astype(np.float32)
        y = rng.normal(size=n).astype(np.float32)
        b = rng.normal(size=p).astype(np.float32)
        resid = x @ b - y
        want = x.T @ resid / n
        got = model.grad_quadratic(jnp.asarray(x.T), jnp.asarray(resid))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


class TestScorePasses:
    def test_score_l1_pass_scales_gradient(self):
        xt = rand((16, 32), 3)
        r = rand((32,), 4)
        beta = jnp.zeros(16, jnp.float32)
        lam = jnp.array([0.05], jnp.float32)
        grad, score = model.score_l1_pass(xt, r, beta, lam)
        want_grad, want_score = ref.score_l1_ref(xt, r, beta, 0.05, 1.0 / 32)
        np.testing.assert_allclose(grad, want_grad, rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(score, want_score, rtol=2e-5, atol=2e-6)

    def test_score_mcp_pass(self):
        xt = rand((16, 32), 5)
        r = rand((32,), 6)
        beta = rand((16,), 7, scale=2.0)
        params = jnp.array([0.1, 3.0], jnp.float32)
        grad, score = model.score_mcp_pass(xt, r, beta, params)
        want_grad, want_score = ref.score_mcp_ref(xt, r, beta, 0.1, 3.0, 1.0 / 32)
        np.testing.assert_allclose(grad, want_grad, rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(score, want_score, rtol=2e-5, atol=2e-6)


class TestObjective:
    def test_objective_quadratic_l1(self):
        xt = rand((8, 16), 8)
        r = rand((16,), 9)
        beta = rand((8,), 10)
        lam = jnp.array([0.3], jnp.float32)
        got = model.objective_quadratic_l1(xt, r, beta, lam)
        want = ref.quad_objective_ref(r, 1.0 / 16) + 0.3 * jnp.sum(jnp.abs(beta))
        np.testing.assert_allclose(got, want, rtol=2e-6)


class TestLowerEntry:
    @pytest.mark.parametrize("op", ["xt_r", "score_l1", "score_mcp", "obj_l1"])
    def test_entry_points_jit_and_return_tuples(self, op):
        n, p = 16, 24
        fn, args = model.lower_entry(op, n, p)
        concrete = [rand(a.shape, i) for i, a in enumerate(args)]
        out = jax.jit(fn)(*concrete)
        assert isinstance(out, tuple)
        for o in out:
            assert bool(jnp.all(jnp.isfinite(o)))

    def test_unknown_op_raises(self):
        with pytest.raises(ValueError):
            model.lower_entry("nope", 8, 8)

    def test_prox_bank_dispatch(self):
        for kind in ["l1", "mcp", "scad"]:
            assert callable(model.prox_bank(kind))
        with pytest.raises(KeyError):
            model.prox_bank("l2")
