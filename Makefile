# Convenience targets; everything is plain cargo underneath.

.PHONY: build test ci bench artifacts clean

build:
	cargo build --release

test:
	cargo test -q --workspace

ci:
	./scripts/ci.sh

# Cold-vs-warm path-scheduler comparison (results/pathsched/)
bench:
	cargo bench --bench path_sched

# AOT-lower the Pallas kernels to HLO text artifacts (needs jax; see
# README.md §PJRT). Safe to skip: the solver falls back to native Rust.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

clean:
	cargo clean
	rm -rf results
